//! BFV homomorphic encryption over R_q = Z_q[X]/(X^N+1), RNS form.
//!
//! Implements exactly what the linear-layer protocol needs:
//! symmetric-key RLWE encryption (the decryptor is always the encryptor — the
//! other party only evaluates), ciphertext⊕ciphertext addition, and
//! ciphertext⊗plaintext multiply-accumulate with NTT-cached plaintext
//! operands. Fresh ciphertexts are seed-compressed (c1 is regenerated from a
//! PRG seed), halving upstream traffic.

use super::bigint::{
    divround_shift64, mul_u128_u64, u192_mod_small, U192,
};
use super::ntt::{add_mod, mul_mod, mul_mod_shoup, shoup, sub_mod, NttTable};
use super::params::{CBD_K, NPRIMES, PRIMES, PSI_16384};
use crate::util::{AesPrg, WorkerPool, Xoshiro256};
use std::sync::Arc;

/// Shared immutable BFV context: NTT tables and CRT constants.
pub struct BfvContext {
    pub n: usize,
    pub tables: Vec<NttTable>,
    /// q = Π q_i as U192, and q/2 for rounding.
    pub q_big: U192,
    q_half: U192,
    /// Δ = floor(q / 2^64) (fits u128 for 180-bit q).
    pub delta: u128,
    /// Δ mod q_i (for plaintext scaling in RNS).
    delta_mod: [u64; NPRIMES],
    /// CRT lift constants: M_i = q / q_i (u128) and y_i = M_i^{-1} mod q_i.
    crt_m: [u128; NPRIMES],
    crt_y: [u64; NPRIMES],
    /// Shoup companions of y_i — `mul_mod_shoup(x, y_i, y_i', q_i)` equals
    /// `mul_mod(x, y_i, q_i)` bit-for-bit, and is what the vectorized CRT
    /// lift uses.
    crt_y_shoup: [u64; NPRIMES],
}

pub type Ctx = Arc<BfvContext>;

impl BfvContext {
    pub fn new(n: usize) -> Ctx {
        assert!(n.is_power_of_two() && n <= 8192);
        let tables: Vec<NttTable> = (0..NPRIMES)
            .map(|i| {
                let q = PRIMES[i];
                // derive primitive 2n-th root from the 16384-th root
                let mut psi = PSI_16384[i];
                let mut order = 16384usize;
                while order > 2 * n {
                    psi = mul_mod(psi, psi, q);
                    order /= 2;
                }
                NttTable::new(q, n, psi)
            })
            .collect();
        // q as U192
        let q01 = PRIMES[0] as u128 * PRIMES[1] as u128;
        let q_big_full = mul_u128_u64(q01, PRIMES[2]);
        // Δ = q >> 64
        let delta = ((q_big_full[2] as u128) << 64) | q_big_full[1] as u128;
        let delta_mod = std::array::from_fn(|i| (delta % PRIMES[i] as u128) as u64);
        // q/2
        let mut q_half = q_big_full;
        let mut carry = 0u64;
        for limb in q_half.iter_mut().rev() {
            let v = ((carry as u128) << 64) | *limb as u128;
            *limb = (v >> 1) as u64;
            carry = (v & 1) as u64;
        }
        // CRT constants
        let mut crt_m = [0u128; NPRIMES];
        let mut crt_y = [0u64; NPRIMES];
        let mut crt_y_shoup = [0u64; NPRIMES];
        for i in 0..NPRIMES {
            let others: Vec<u64> =
                (0..NPRIMES).filter(|&j| j != i).map(|j| PRIMES[j]).collect();
            let m = others[0] as u128 * others[1] as u128;
            crt_m[i] = m;
            let m_mod = (m % PRIMES[i] as u128) as u64;
            crt_y[i] = super::ntt::inv_mod(m_mod, PRIMES[i]);
            crt_y_shoup[i] = shoup(crt_y[i], PRIMES[i]);
        }
        Arc::new(BfvContext {
            n,
            tables,
            q_big: q_big_full,
            q_half,
            delta,
            delta_mod,
            crt_m,
            crt_y,
            crt_y_shoup,
        })
    }

    /// Total bytes of one full (uncompressed) ciphertext on the wire.
    pub fn ct_bytes(&self) -> usize {
        2 * NPRIMES * self.n * 8
    }

    /// Bytes of a seed-compressed fresh ciphertext.
    pub fn fresh_ct_bytes(&self) -> usize {
        NPRIMES * self.n * 8 + 8
    }
}

/// RNS polynomial: one residue vector per prime.
#[derive(Clone, Debug, PartialEq)]
pub struct RnsPoly {
    pub res: Vec<Vec<u64>>, // [prime][coeff]
    pub ntt: bool,
}

impl RnsPoly {
    pub fn zero(ctx: &BfvContext, ntt: bool) -> Self {
        RnsPoly { res: vec![vec![0u64; ctx.n]; NPRIMES], ntt }
    }

    /// Lift u64 plaintext coefficients (mod 2^64 values) into RNS residues.
    pub fn from_u64_coeffs(ctx: &BfvContext, coeffs: &[u64]) -> Self {
        assert_eq!(coeffs.len(), ctx.n);
        let res = (0..NPRIMES)
            .map(|i| {
                let q = PRIMES[i];
                coeffs.iter().map(|&c| c % q).collect()
            })
            .collect();
        RnsPoly { res, ntt: false }
    }

    pub fn forward_ntt(&mut self, ctx: &BfvContext) {
        self.forward_ntt_with(ctx, WorkerPool::single());
    }

    /// Forward NTT with the per-prime passes spread over `pool` (each prime's
    /// residue vector is independent). Used by paths that are not already
    /// parallel at a coarser (per-tile) granularity.
    pub fn forward_ntt_with(&mut self, ctx: &BfvContext, pool: WorkerPool) {
        assert!(!self.ntt);
        pool.sized_for(NPRIMES, 1)
            .par_for_each_mut(&mut self.res, |i, r| ctx.tables[i].forward(r));
        self.ntt = true;
    }

    pub fn inverse_ntt(&mut self, ctx: &BfvContext) {
        self.inverse_ntt_with(ctx, WorkerPool::single());
    }

    /// Inverse NTT with the per-prime passes spread over `pool`.
    pub fn inverse_ntt_with(&mut self, ctx: &BfvContext, pool: WorkerPool) {
        assert!(self.ntt);
        pool.sized_for(NPRIMES, 1)
            .par_for_each_mut(&mut self.res, |i, r| ctx.tables[i].inverse(r));
        self.ntt = false;
    }

    pub fn add_assign(&mut self, other: &RnsPoly) {
        assert_eq!(self.ntt, other.ntt);
        for i in 0..NPRIMES {
            let q = PRIMES[i];
            for (a, &b) in self.res[i].iter_mut().zip(&other.res[i]) {
                *a = add_mod(*a, b, q);
            }
        }
    }

    pub fn sub_assign(&mut self, other: &RnsPoly) {
        assert_eq!(self.ntt, other.ntt);
        for i in 0..NPRIMES {
            let q = PRIMES[i];
            for (a, &b) in self.res[i].iter_mut().zip(&other.res[i]) {
                *a = sub_mod(*a, b, q);
            }
        }
    }

    /// Serialize residues to a flat u64 vector (for channel transport).
    pub fn to_u64s(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(NPRIMES * self.res[0].len());
        for r in &self.res {
            out.extend_from_slice(r);
        }
        out
    }

    pub fn from_u64s(ctx: &BfvContext, flat: &[u64], ntt: bool) -> Self {
        assert_eq!(flat.len(), NPRIMES * ctx.n);
        let res = (0..NPRIMES)
            .map(|i| flat[i * ctx.n..(i + 1) * ctx.n].to_vec())
            .collect();
        RnsPoly { res, ntt }
    }
}

/// Plaintext operand cached in NTT form with Shoup companions — a ct⊗pt
/// multiply against this is two integer multiplies per coefficient.
pub struct PtNtt {
    pub vals: Vec<Vec<u64>>,  // [prime][coeff], NTT domain
    pub shoup: Vec<Vec<u64>>, // Shoup quotients
}

impl PtNtt {
    /// Encode signed-magnitude plaintext coefficients (two's-complement u64,
    /// e.g. fixed-point weights) into cached NTT form. The value is reduced
    /// *as a signed integer* into each prime field so small negative weights
    /// stay small.
    pub fn encode(ctx: &BfvContext, coeffs: &[u64]) -> Self {
        Self::encode_with(ctx, coeffs, WorkerPool::single())
    }

    /// [`encode`](Self::encode) with the per-prime reduce + NTT + Shoup
    /// passes spread over `pool` (used when the caller has a single tile and
    /// cannot parallelize at tile granularity).
    pub fn encode_with(ctx: &BfvContext, coeffs: &[u64], pool: WorkerPool) -> Self {
        assert_eq!(coeffs.len(), ctx.n);
        let per_prime: Vec<(Vec<u64>, Vec<u64>)> =
            pool.sized_for(NPRIMES, 1).par_map(NPRIMES, |i| {
                let q = PRIMES[i];
                let mut v: Vec<u64> = coeffs
                    .iter()
                    .map(|&c| {
                        let s = c as i64;
                        if s < 0 {
                            q - ((s.unsigned_abs()) % q)
                        } else {
                            (s as u64) % q
                        }
                    })
                    .collect();
                ctx.tables[i].forward(&mut v);
                let sh = v.iter().map(|&w| shoup(w, q)).collect();
                (v, sh)
            });
        let (vals, shoup_q) = per_prime.into_iter().unzip();
        PtNtt { vals, shoup: shoup_q }
    }
}

/// Ternary secret key, stored in NTT form per prime for fast c1·s.
pub struct SecretKey {
    s_ntt: RnsPoly,
}

impl SecretKey {
    pub fn gen(ctx: &BfvContext, rng: &mut Xoshiro256) -> Self {
        let mut coeffs = vec![0u64; ctx.n];
        for c in coeffs.iter_mut() {
            *c = match rng.below(3) {
                0 => 0,
                1 => 1,
                _ => u64::MAX, // -1
            };
        }
        let mut s = RnsPoly::from_u64_coeffs_signed(ctx, &coeffs);
        s.forward_ntt(ctx);
        SecretKey { s_ntt: s }
    }
}

impl RnsPoly {
    /// Lift signed two's-complement u64 coefficients into RNS (centered).
    pub fn from_u64_coeffs_signed(_ctx: &BfvContext, coeffs: &[u64]) -> Self {
        let res = (0..NPRIMES)
            .map(|i| {
                let q = PRIMES[i];
                coeffs
                    .iter()
                    .map(|&c| {
                        let s = c as i64;
                        if s < 0 {
                            q - (s.unsigned_abs() % q)
                        } else {
                            s as u64 % q
                        }
                    })
                    .collect()
            })
            .collect();
        RnsPoly { res, ntt: false }
    }
}

/// A BFV ciphertext (c0, c1) with Dec(c) = round(t·(c0 + c1·s)/q) mod t.
/// `c1_seed` is set for fresh seed-compressed ciphertexts.
pub struct Ciphertext {
    pub c0: RnsPoly,
    pub c1: RnsPoly,
    pub c1_seed: Option<u64>,
}

fn expand_seed_poly(ctx: &BfvContext, seed: u64) -> RnsPoly {
    // uniform polynomial per prime from an AES-CTR stream (NTT domain);
    // bulk-filled so AES-NI pipelines the CTR blocks (§Perf).
    let mut prg = AesPrg::from_u64_seed(seed);
    let mut buf = vec![0u64; ctx.n];
    let res = (0..NPRIMES)
        .map(|i| {
            let q = PRIMES[i];
            prg.fill_u64(&mut buf);
            // rejection-free: modulo bias < 2^-4 is irrelevant here
            buf.iter().map(|&v| v % q).collect()
        })
        .collect();
    RnsPoly { res, ntt: true }
}

fn sample_cbd(ctx: &BfvContext, rng: &mut Xoshiro256) -> RnsPoly {
    let mut coeffs = vec![0u64; ctx.n];
    for c in coeffs.iter_mut() {
        let bits = rng.next_u64();
        let a = (bits & ((1 << CBD_K) - 1)).count_ones() as i64;
        let b = ((bits >> CBD_K) & ((1 << CBD_K) - 1)).count_ones() as i64;
        *c = (a - b) as u64;
    }
    RnsPoly::from_u64_coeffs_signed(ctx, &coeffs)
}

/// Symmetric encryption of plaintext coefficients m ∈ (Z_2^64)^N.
/// Output is in NTT form, ready for evaluation; c1 is seed-compressed.
pub fn encrypt(
    ctx: &BfvContext,
    sk: &SecretKey,
    m: &[u64],
    rng: &mut Xoshiro256,
) -> Ciphertext {
    let seed = rng.next_u64();
    let a = expand_seed_poly(ctx, seed); // NTT domain
    // c0 = Δ·m + e − a·s  (all in NTT domain)
    let mut dm = RnsPoly::zero(ctx, false);
    for i in 0..NPRIMES {
        let q = PRIMES[i];
        let dq = ctx.delta_mod[i];
        for (j, &mj) in m.iter().enumerate() {
            dm.res[i][j] = mul_mod(dq, mj % q, q);
        }
    }
    let mut e = sample_cbd(ctx, rng);
    e.add_assign(&dm);
    e.forward_ntt(ctx); // now Δm+e in NTT
    let mut c0 = e;
    // subtract a·s
    for i in 0..NPRIMES {
        let q = PRIMES[i];
        for j in 0..ctx.n {
            let as_ = mul_mod(a.res[i][j], sk.s_ntt.res[i][j], q);
            c0.res[i][j] = sub_mod(c0.res[i][j], as_, q);
        }
    }
    Ciphertext { c0, c1: a, c1_seed: Some(seed) }
}

/// Decrypt to plaintext coefficients mod 2^64.
pub fn decrypt(ctx: &BfvContext, sk: &SecretKey, ct: &Ciphertext) -> Vec<u64> {
    decrypt_with(ctx, sk, ct, WorkerPool::single())
}

/// [`decrypt`] with the heavy per-coefficient work — c1·s multiply-add,
/// inverse NTT, and the U192 CRT lift + rounding — spread over `pool`.
/// Bit-identical output at any pool size. Callers that decrypt *many*
/// ciphertexts parallelize across them instead and pass a single pool here.
pub fn decrypt_with(
    ctx: &BfvContext,
    sk: &SecretKey,
    ct: &Ciphertext,
    pool: WorkerPool,
) -> Vec<u64> {
    let mut scratch = RnsPoly::zero(ctx, true);
    decrypt_with_scratch(ctx, sk, ct, pool, &mut scratch)
}

/// [`decrypt_with`] reusing a caller-provided scratch polynomial for the
/// intermediate c0 + c1·s — batched decrypt loops (one scratch per worker)
/// avoid an NPRIMES×N allocation per ciphertext. `scratch` contents are
/// overwritten; its shape must match `ctx`.
pub fn decrypt_with_scratch(
    ctx: &BfvContext,
    sk: &SecretKey,
    ct: &Ciphertext,
    pool: WorkerPool,
    scratch: &mut RnsPoly,
) -> Vec<u64> {
    assert!(ct.c0.ntt && ct.c1.ntt);
    assert_eq!(scratch.res.len(), NPRIMES);
    let use_simd = super::simd::enabled();
    // x = c0 + c1·s per prime (written into scratch), then inverse NTT
    scratch.ntt = true;
    pool.sized_for(NPRIMES, 1).par_for_each_mut(&mut scratch.res, |i, r| {
        assert_eq!(r.len(), ctx.n);
        let q = PRIMES[i];
        let c0 = &ct.c0.res[i];
        let c1 = &ct.c1.res[i];
        let s = &sk.s_ntt.res[i];
        for (j, v) in r.iter_mut().enumerate() {
            *v = add_mod(c0[j], mul_mod(c1[j], s[j], q), q);
        }
    });
    scratch.inverse_ntt_with(ctx, pool);
    // per-prime CRT-lift terms x_i·y_i mod q_i, in place — strict Shoup by
    // the broadcast constant y_i, bit-identical to mul_mod (vectorizable)
    pool.sized_for(NPRIMES, 1).par_for_each_mut(&mut scratch.res, |i, r| {
        let q = PRIMES[i];
        let (y, yp) = (ctx.crt_y[i], ctx.crt_y_shoup[i]);
        if !(use_simd && super::simd::try_mul_shoup_const(r, y, yp, q)) {
            for v in r.iter_mut() {
                *v = mul_mod_shoup(*v, y, yp, q);
            }
        }
    });
    // accumulate the lift and round: m = round(x·2^64 / q) mod 2^64
    let terms = &scratch.res;
    pool.sized_for(ctx.n, 1024).par_map(ctx.n, |j| {
        let mut acc: U192 = [0, 0, 0];
        for (i, t) in terms.iter().enumerate() {
            let prod = mul_u128_u64(ctx.crt_m[i], t[j]);
            acc = super::bigint::u192_add(acc, prod);
        }
        let lifted = u192_mod_small(acc, ctx.q_big);
        divround_shift64(lifted, ctx.q_half, ctx.q_big)
    })
}

impl Ciphertext {
    /// Homomorphic c += ct ⊗ pt (NTT-domain multiply-accumulate).
    pub fn mul_pt_accumulate(&mut self, ct: &Ciphertext, pt: &PtNtt) {
        assert!(self.c0.ntt && ct.c0.ntt);
        for i in 0..NPRIMES {
            let q = PRIMES[i];
            let (pv, ps) = (&pt.vals[i], &pt.shoup[i]);
            let dst0 = &mut self.c0.res[i];
            let src0 = &ct.c0.res[i];
            for j in 0..dst0.len() {
                let p = mul_mod_shoup(src0[j], pv[j], ps[j], q);
                dst0[j] = add_mod(dst0[j], p, q);
            }
            let dst1 = &mut self.c1.res[i];
            let src1 = &ct.c1.res[i];
            for j in 0..dst1.len() {
                let p = mul_mod_shoup(src1[j], pv[j], ps[j], q);
                dst1[j] = add_mod(dst1[j], p, q);
            }
        }
    }

    /// Lazy-reduction variant of [`mul_pt_accumulate`](Self::mul_pt_accumulate):
    /// residues accumulate in [0, 2q) — the Shoup product is left unreduced
    /// (< 2q) and the running sum gets a single conditional 2q subtraction
    /// instead of two canonical reductions per coefficient. Sums stay below
    /// 4q < 2^62, so u64 never overflows. Call [`normalize`](Self::normalize)
    /// after the last accumulate of a chain; decryption, further homomorphic
    /// ops, and (transcript-determinism!) serialization all require canonical
    /// residues.
    pub fn mul_pt_accumulate_lazy(&mut self, ct: &Ciphertext, pt: &PtNtt) {
        self.mul_pt_accumulate_lazy_with(ct, pt, crate::he::simd::enabled());
    }

    /// [`mul_pt_accumulate_lazy`](Self::mul_pt_accumulate_lazy) with the
    /// dispatch decision forced (tests/benches). Both paths keep the same
    /// lazy [0, 2q) bounds and produce bit-identical residues.
    pub fn mul_pt_accumulate_lazy_with(
        &mut self,
        ct: &Ciphertext,
        pt: &PtNtt,
        use_simd: bool,
    ) {
        assert!(self.c0.ntt && ct.c0.ntt);
        for i in 0..NPRIMES {
            let q = PRIMES[i];
            let two_q = 2 * q;
            let (pv, ps) = (&pt.vals[i], &pt.shoup[i]);
            for (dst, src) in [
                (&mut self.c0.res[i], &ct.c0.res[i]),
                (&mut self.c1.res[i], &ct.c1.res[i]),
            ] {
                if use_simd && super::simd::try_mul_acc_lazy(dst, src, pv, ps, q) {
                    continue;
                }
                for j in 0..dst.len() {
                    let p = super::ntt::mul_mod_shoup_lazy(src[j], pv[j], ps[j], q);
                    let s = dst[j] + p;
                    dst[j] = if s >= two_q { s - two_q } else { s };
                }
            }
        }
    }

    /// Reduce residues from the lazy [0, 2q) range back to canonical [0, q).
    pub fn normalize(&mut self) {
        for i in 0..NPRIMES {
            let q = PRIMES[i];
            for v in self.c0.res[i].iter_mut().chain(self.c1.res[i].iter_mut()) {
                if *v >= q {
                    *v -= q;
                }
            }
        }
    }

    /// Homomorphic addition of a plaintext vector (Δ-scaled): used by the
    /// evaluator to add its output mask −r before returning the ciphertext.
    pub fn add_plain(&mut self, ctx: &BfvContext, m: &[u64]) {
        assert!(self.c0.ntt);
        let mut dm = RnsPoly::zero(ctx, false);
        for i in 0..NPRIMES {
            let q = PRIMES[i];
            let dq = ctx.delta_mod[i];
            for (j, &mj) in m.iter().enumerate() {
                dm.res[i][j] = mul_mod(dq, mj % q, q);
            }
        }
        dm.forward_ntt(ctx);
        self.c0.add_assign(&dm);
    }

    pub fn zero_like(ctx: &BfvContext) -> Ciphertext {
        Ciphertext {
            c0: RnsPoly::zero(ctx, true),
            c1: RnsPoly::zero(ctx, true),
            c1_seed: None,
        }
    }

    /// Wire format: fresh compressed (seed + c0) or full (c0 ‖ c1).
    pub fn to_wire(&self) -> Vec<u64> {
        match self.c1_seed {
            Some(seed) => {
                let mut v = vec![1u64, seed];
                v.extend(self.c0.to_u64s());
                v
            }
            None => {
                let mut v = vec![0u64, 0u64];
                v.extend(self.c0.to_u64s());
                v.extend(self.c1.to_u64s());
                v
            }
        }
    }

    pub fn from_wire(ctx: &BfvContext, flat: &[u64]) -> Ciphertext {
        let tag = flat[0];
        let seed = flat[1];
        let body = &flat[2..];
        if tag == 1 {
            let c0 = RnsPoly::from_u64s(ctx, &body[..NPRIMES * ctx.n], true);
            let c1 = expand_seed_poly(ctx, seed);
            Ciphertext { c0, c1, c1_seed: Some(seed) }
        } else {
            let c0 = RnsPoly::from_u64s(ctx, &body[..NPRIMES * ctx.n], true);
            let c1 = RnsPoly::from_u64s(ctx, &body[NPRIMES * ctx.n..], true);
            Ciphertext { c0, c1, c1_seed: None }
        }
    }
}

/// Invariant-noise budget in bits (for tests/diagnostics): measures
/// log2(q / (2·|q·frac(t·x/q)|_∞)) — how many doublings of noise remain.
pub fn noise_budget(
    ctx: &BfvContext,
    sk: &SecretKey,
    ct: &Ciphertext,
    expected_m: &[u64],
) -> f64 {
    // decrypt and compare Δ·m to x — the residual is the noise
    let mut x = ct.c0.clone();
    for i in 0..NPRIMES {
        let q = PRIMES[i];
        for j in 0..ctx.n {
            let cs = mul_mod(ct.c1.res[i][j], sk.s_ntt.res[i][j], q);
            x.res[i][j] = add_mod(x.res[i][j], cs, q);
        }
    }
    x.inverse_ntt(ctx);
    let mut max_noise_bits: f64 = 0.0;
    for j in 0..ctx.n {
        // noise = x − Δ·m (mod q), centered
        let mut acc: U192 = [0, 0, 0];
        for i in 0..NPRIMES {
            let xi = x.res[i][j];
            let term = mul_mod(xi, ctx.crt_y[i], PRIMES[i]);
            acc = super::bigint::u192_add(acc, mul_u128_u64(ctx.crt_m[i], term));
        }
        let lifted = u192_mod_small(acc, ctx.q_big);
        let dm = mul_u128_u64(ctx.delta, expected_m[j]);
        // noise = lifted − Δm mod q, take min(v, q−v)
        let diff = if super::bigint::u192_geq(lifted, dm) {
            super::bigint::u192_sub(lifted, dm)
        } else {
            super::bigint::u192_sub(super::bigint::u192_add(lifted, ctx.q_big), dm)
        };
        let diff_c = if super::bigint::u192_geq(diff, ctx.q_half) {
            super::bigint::u192_sub(ctx.q_big, diff)
        } else {
            diff
        };
        let bits = if diff_c[2] != 0 {
            192 - diff_c[2].leading_zeros() as i64
        } else if diff_c[1] != 0 {
            128 - diff_c[1].leading_zeros() as i64
        } else if diff_c[0] != 0 {
            64 - diff_c[0].leading_zeros() as i64
        } else {
            0
        };
        max_noise_bits = max_noise_bits.max(bits as f64);
    }
    // budget = log2(q) − noise_bits − 1
    180.0 - max_noise_bits - 1.0
}

pub fn q_mod_t_is_small(_ctx: &BfvContext) -> bool {
    true // see params.rs: q/t ≈ 2^116 makes ρ irrelevant for our magnitudes
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(n: usize) -> (Ctx, SecretKey, Xoshiro256) {
        let ctx = BfvContext::new(n);
        let mut rng = Xoshiro256::seed_from_u64(42);
        let sk = SecretKey::gen(&ctx, &mut rng);
        (ctx, sk, rng)
    }

    #[test]
    fn encrypt_decrypt_roundtrip() {
        let (ctx, sk, mut rng) = setup(1024);
        let m: Vec<u64> = (0..ctx.n as u64).map(|i| i.wrapping_mul(0x9E3779B9)).collect();
        let ct = encrypt(&ctx, &sk, &m, &mut rng);
        let got = decrypt(&ctx, &sk, &ct);
        assert_eq!(got, m);
    }

    #[test]
    fn decrypt_full_range_values() {
        let (ctx, sk, mut rng) = setup(256);
        let mut m = vec![0u64; ctx.n];
        m[0] = u64::MAX;
        m[1] = 1 << 63;
        m[2] = (1 << 63) - 1;
        m[3] = (-5i64) as u64;
        let ct = encrypt(&ctx, &sk, &m, &mut rng);
        assert_eq!(decrypt(&ctx, &sk, &ct), m);
    }

    #[test]
    fn homomorphic_add_plain() {
        let (ctx, sk, mut rng) = setup(256);
        let m: Vec<u64> = (0..ctx.n as u64).collect();
        let r: Vec<u64> = (0..ctx.n).map(|_| rng.next_u64()).collect();
        let mut ct = encrypt(&ctx, &sk, &m, &mut rng);
        ct.add_plain(&ctx, &r);
        let got = decrypt(&ctx, &sk, &ct);
        for j in 0..ctx.n {
            assert_eq!(got[j], m[j].wrapping_add(r[j]), "j={j}");
        }
    }

    #[test]
    fn ct_pt_multiply_is_negacyclic_convolution() {
        let (ctx, sk, mut rng) = setup(256);
        // message: small mixed-sign values; pt: small signed weights
        let m: Vec<u64> = (0..ctx.n)
            .map(|j| ((j as i64 % 17) - 8) as u64)
            .collect();
        let mut w = vec![0u64; ctx.n];
        w[0] = 3;
        w[1] = (-2i64) as u64;
        w[5] = 7;
        let ct = encrypt(&ctx, &sk, &m, &mut rng);
        let pt = PtNtt::encode(&ctx, &w);
        let mut acc = Ciphertext::zero_like(&ctx);
        acc.mul_pt_accumulate(&ct, &pt);
        let got = decrypt(&ctx, &sk, &acc);
        // reference negacyclic convolution mod 2^64
        let mut expect = vec![0u64; ctx.n];
        for i in 0..ctx.n {
            if w[i] == 0 {
                continue;
            }
            for j in 0..ctx.n {
                let p = m[j].wrapping_mul(w[i]);
                let k = i + j;
                if k < ctx.n {
                    expect[k] = expect[k].wrapping_add(p);
                } else {
                    expect[k - ctx.n] = expect[k - ctx.n].wrapping_sub(p);
                }
            }
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn ct_pt_multiply_uniform_shares() {
        // the critical case for the matmul protocol: message coefficients are
        // *uniform* ring elements (secret shares)
        let (ctx, sk, mut rng) = setup(256);
        let m: Vec<u64> = (0..ctx.n).map(|_| rng.next_u64()).collect();
        let mut w = vec![0u64; ctx.n];
        for i in 0..16 {
            w[i] = ((rng.next_u64() % 16384) as i64 - 8192) as u64; // |w| < 2^13
        }
        let ct = encrypt(&ctx, &sk, &m, &mut rng);
        let pt = PtNtt::encode(&ctx, &w);
        let mut acc = Ciphertext::zero_like(&ctx);
        acc.mul_pt_accumulate(&ct, &pt);
        let got = decrypt(&ctx, &sk, &acc);
        let mut expect = vec![0u64; ctx.n];
        for i in 0..ctx.n {
            if w[i] == 0 {
                continue;
            }
            for j in 0..ctx.n {
                let p = m[j].wrapping_mul(w[i]);
                let k = i + j;
                if k < ctx.n {
                    expect[k] = expect[k].wrapping_add(p);
                } else {
                    expect[k - ctx.n] = expect[k - ctx.n].wrapping_sub(p);
                }
            }
        }
        assert_eq!(got, expect);
    }

    #[test]
    fn accumulate_many_products_stays_correct() {
        let (ctx, sk, mut rng) = setup(256);
        let mut acc = Ciphertext::zero_like(&ctx);
        let mut expect = vec![0u64; ctx.n];
        for round in 0..8 {
            let m: Vec<u64> = (0..ctx.n).map(|_| rng.next_u64()).collect();
            let mut w = vec![0u64; ctx.n];
            w[round] = (round as u64) + 2;
            let ct = encrypt(&ctx, &sk, &m, &mut rng);
            let pt = PtNtt::encode(&ctx, &w);
            acc.mul_pt_accumulate(&ct, &pt);
            for j in 0..ctx.n {
                let k = round + j;
                let p = m[j].wrapping_mul(w[round]);
                if k < ctx.n {
                    expect[k] = expect[k].wrapping_add(p);
                } else {
                    expect[k - ctx.n] = expect[k - ctx.n].wrapping_sub(p);
                }
            }
        }
        assert_eq!(decrypt(&ctx, &sk, &acc), expect);
    }

    /// Lazy-reduction accumulate must agree with the strict reference for
    /// every kt-chain length the matmul plans produce, including chains whose
    /// intermediate residues cross the q boundary (uniform-share messages put
    /// mass in [q, 2q) from the very first lazy accumulate).
    #[test]
    fn lazy_accumulate_matches_strict_across_chain_lengths() {
        let (ctx, sk, mut rng) = setup(256);
        for &chain in &[1usize, 2, 3, 5, 8, 13] {
            let mut strict = Ciphertext::zero_like(&ctx);
            let mut lazy = Ciphertext::zero_like(&ctx);
            let mut crossed_q = false;
            for step in 0..chain {
                let m: Vec<u64> = (0..ctx.n).map(|_| rng.next_u64()).collect();
                let mut w = vec![0u64; ctx.n];
                for wi in w.iter_mut().take(8) {
                    *wi = ((rng.next_u64() % 16384) as i64 - 8192) as u64;
                }
                w[step % ctx.n] = w[step % ctx.n].wrapping_add(1); // never all-zero
                let ct = encrypt(&ctx, &sk, &m, &mut rng);
                let pt = PtNtt::encode(&ctx, &w);
                strict.mul_pt_accumulate(&ct, &pt);
                lazy.mul_pt_accumulate_lazy(&ct, &pt);
                crossed_q = crossed_q
                    || (0..NPRIMES).any(|i| {
                        lazy.c0.res[i].iter().any(|&v| v >= PRIMES[i])
                    });
            }
            assert!(crossed_q, "chain {chain}: lazy range [q, 2q) never exercised");
            lazy.normalize();
            assert_eq!(lazy.c0, strict.c0, "chain {chain}: c0 residues");
            assert_eq!(lazy.c1, strict.c1, "chain {chain}: c1 residues");
            assert_eq!(
                decrypt(&ctx, &sk, &lazy),
                decrypt(&ctx, &sk, &strict),
                "chain {chain}: decryptions"
            );
        }
    }

    #[test]
    fn decrypt_with_pool_matches_sequential() {
        // n = 2048 so the CRT-lift stage (min 1024 coeffs/thread) actually
        // splits across workers instead of degrading to one
        let (ctx, sk, mut rng) = setup(2048);
        let m: Vec<u64> = (0..ctx.n).map(|_| rng.next_u64()).collect();
        let ct = encrypt(&ctx, &sk, &m, &mut rng);
        let seq = decrypt(&ctx, &sk, &ct);
        for threads in [2, 3, 8] {
            let par = decrypt_with(&ctx, &sk, &ct, WorkerPool::new(threads));
            assert_eq!(par, seq, "threads={threads}");
        }
        assert_eq!(seq, m);
    }

    #[test]
    fn wire_roundtrip_fresh_and_full() {
        let (ctx, sk, mut rng) = setup(256);
        let m: Vec<u64> = (0..ctx.n).map(|_| rng.next_u64()).collect();
        let ct = encrypt(&ctx, &sk, &m, &mut rng);
        // fresh compressed
        let wire = ct.to_wire();
        assert_eq!(wire.len(), 2 + NPRIMES * ctx.n);
        let ct2 = Ciphertext::from_wire(&ctx, &wire);
        assert_eq!(decrypt(&ctx, &sk, &ct2), m);
        // full
        let mut acc = Ciphertext::zero_like(&ctx);
        let mut w = vec![0u64; ctx.n];
        w[0] = 1;
        acc.mul_pt_accumulate(&ct2, &PtNtt::encode(&ctx, &w));
        let wire2 = acc.to_wire();
        assert_eq!(wire2.len(), 2 + 2 * NPRIMES * ctx.n);
        let ct3 = Ciphertext::from_wire(&ctx, &wire2);
        assert_eq!(decrypt(&ctx, &sk, &ct3), m);
    }

    #[test]
    fn noise_budget_is_large_for_fresh() {
        let (ctx, sk, mut rng) = setup(256);
        let m: Vec<u64> = (0..ctx.n).map(|_| rng.next_u64()).collect();
        let ct = encrypt(&ctx, &sk, &m, &mut rng);
        let nb = noise_budget(&ctx, &sk, &ct, &m);
        assert!(nb > 100.0, "budget={nb}");
    }
}
