//! BFV parameter set.
//!
//! - Ring degree N = 8192, ciphertext modulus q = q0·q1·q2 (three ~60-bit
//!   NTT-friendly primes, log q ≈ 180) — ≥128-bit RLWE security at this
//!   (N, log q) point (cf. the HE standard tables; IRON/Cheetah use comparable
//!   margins).
//! - Plaintext modulus t = 2^64 — *exactly the secret-sharing ring* Z_2^64, so
//!   homomorphic results drop directly into additive shares with no ring
//!   conversion. Correctness of Δ-scaling with t ∤ q holds because
//!   q/t ≈ 2^116 dwarfs the worst-case message·weight magnitude (~2^90):
//!   the rounding error term m·w·(q mod t)/q ≤ 2^(90+64−180) « 1/2.
//! - Secret key ternary; noise from a centered binomial (σ ≈ 3.2).

/// Ring degree.
pub const N: usize = 8192;

/// Number of RNS primes.
pub const NPRIMES: usize = 3;

/// NTT-friendly primes ≡ 1 (mod 16384), just below 2^60.
pub const PRIMES: [u64; NPRIMES] =
    [1152921504606830593, 1152921504606748673, 1152921504606683137];

/// Primitive 16384-th roots of unity for each prime (ψ with ψ^8192 = −1).
pub const PSI_16384: [u64; NPRIMES] =
    [330791804103690911, 609248293264176271, 353405849166470586];

/// Centered-binomial parameter: e = Σ_{i<CBD_K} b_i − Σ_{i<CBD_K} b'_i,
/// variance CBD_K/2 (σ ≈ 3.2 for K = 20).
pub const CBD_K: usize = 20;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::he::ntt::pow_mod;

    #[test]
    fn modulus_magnitudes() {
        for &q in &PRIMES {
            assert!(q < 1u64 << 60);
            assert!(q > 1u64 << 59);
            assert_eq!((q - 1) % (2 * N as u64), 0);
        }
    }

    #[test]
    fn roots_have_exact_order() {
        for i in 0..NPRIMES {
            let (q, psi) = (PRIMES[i], PSI_16384[i]);
            assert_eq!(pow_mod(psi, 16384, q), 1);
            assert_ne!(pow_mod(psi, 8192, q), 1);
            assert_eq!(pow_mod(psi, 8192, q), q - 1);
        }
    }
}
