//! Homomorphic-encryption substrate: BFV over RNS with negacyclic NTT, plus
//! the coefficient-packed matrix-multiplication encoding used by the linear
//! layers (IRON-style; see DESIGN.md for the BOLT BSGS substitution note).

pub mod bfv;
pub mod bigint;
pub mod matmul;
pub mod ntt;
pub mod params;

pub use bfv::{decrypt, decrypt_with, encrypt, BfvContext, Ciphertext, Ctx, PtNtt, SecretKey};
pub use matmul::MatmulPlan;
