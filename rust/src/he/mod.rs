//! Homomorphic-encryption substrate: BFV over RNS with negacyclic NTT, plus
//! the coefficient-packed matrix-multiplication encoding used by the linear
//! layers (IRON-style; see DESIGN.md for the BOLT BSGS substitution note).
//!
//! # Vectorized kernels
//!
//! The per-coefficient inner loops — the Harvey NTT butterflies
//! (`ntt::NttTable::{forward, inverse}`), the lazy Shoup multiply-accumulate
//! (`Ciphertext::mul_pt_accumulate_lazy`, and through the NTT dispatch the
//! `PtNtt` weight encoding), and the per-prime CRT-lift terms in
//! `decrypt_with` — have AVX2 implementations in [`simd`], selected at
//! runtime via `is_x86_feature_detected!("avx2")` and overridable with the
//! `CIPHERPRUNE_SIMD` env var or `EngineConfig::simd`. The scalar code is
//! kept verbatim as the portable fallback and bit-identity reference: both
//! paths use the same lazy-reduction bounds and final reductions, so
//! ciphertexts, transcripts, and digests are identical either way.
//!
//! `unsafe` is confined to [`simd`] (and its OT sibling `crate::ot::simd`)
//! behind a scoped `#![allow(unsafe_code)]` with a documented safety
//! contract — the crate denies `unsafe_code` everywhere else and mpc-lint's
//! `unsafe` rule enforces the confinement.

pub mod bfv;
pub mod bigint;
pub mod matmul;
pub mod ntt;
pub mod params;
pub mod simd;

pub use bfv::{
    decrypt, decrypt_with, decrypt_with_scratch, encrypt, BfvContext, Ciphertext, Ctx, PtNtt,
    SecretKey,
};
pub use matmul::MatmulPlan;
