//! Minimal fixed-width big-integer helpers for BFV's CRT/decryption arithmetic.
//!
//! The RNS modulus q = q0·q1 is ~120 bits; decryption needs
//! round(x·2^64 / q) for x < q, i.e. a 184-bit numerator divided by a 120-bit
//! divisor with a quotient < 2^64. We implement just the ops needed:
//! little-endian [u64; 3] ("U192") add/sub/cmp/mul and a one-limb-quotient
//! Knuth-D division.

pub type U192 = [u64; 3];

pub const U192_ZERO: U192 = [0, 0, 0];

pub fn u192_from_u128(x: u128) -> U192 {
    [x as u64, (x >> 64) as u64, 0]
}

pub fn u192_to_u128(x: U192) -> u128 {
    debug_assert_eq!(x[2], 0, "u192 too large for u128");
    (x[1] as u128) << 64 | x[0] as u128
}

pub fn u192_add(a: U192, b: U192) -> U192 {
    let (l0, c0) = a[0].overflowing_add(b[0]);
    let (l1a, c1a) = a[1].overflowing_add(b[1]);
    let (l1, c1b) = l1a.overflowing_add(c0 as u64);
    let l2 = a[2]
        .wrapping_add(b[2])
        .wrapping_add((c1a as u64) + (c1b as u64));
    [l0, l1, l2]
}

pub fn u192_sub(a: U192, b: U192) -> U192 {
    let (l0, b0) = a[0].overflowing_sub(b[0]);
    let (l1a, b1a) = a[1].overflowing_sub(b[1]);
    let (l1, b1b) = l1a.overflowing_sub(b0 as u64);
    let l2 = a[2]
        .wrapping_sub(b[2])
        .wrapping_sub((b1a as u64) + (b1b as u64));
    [l0, l1, l2]
}

pub fn u192_cmp(a: U192, b: U192) -> std::cmp::Ordering {
    for i in (0..3).rev() {
        match a[i].cmp(&b[i]) {
            std::cmp::Ordering::Equal => continue,
            o => return o,
        }
    }
    std::cmp::Ordering::Equal
}

pub fn u192_geq(a: U192, b: U192) -> bool {
    u192_cmp(a, b) != std::cmp::Ordering::Less
}

/// a (u128) × b (u64) -> U192.
pub fn mul_u128_u64(a: u128, b: u64) -> U192 {
    let lo = (a as u64) as u128 * b as u128;
    let hi = (a >> 64) * b as u128;
    let l0 = lo as u64;
    let mid = (lo >> 64) + (hi as u64 as u128);
    let l1 = mid as u64;
    let l2 = ((mid >> 64) + (hi >> 64)) as u64;
    [l0, l1, l2]
}

/// U192 modulo a reduction by conditional subtraction; requires a < 4·m.
pub fn u192_mod_small(mut a: U192, m: U192) -> U192 {
    for _ in 0..3 {
        if u192_geq(a, m) {
            a = u192_sub(a, m);
        } else {
            break;
        }
    }
    debug_assert!(!u192_geq(a, m));
    a
}

/// floor((x·2^64 + r) / d) where x < d, d is a U192 with d[2] possibly 0, and
/// r < d. Quotient is < 2^64. Knuth-D style with normalization.
pub fn divround_shift64(x: U192, r: U192, d: U192) -> u64 {
    debug_assert!(u192_cmp(x, d) == std::cmp::Ordering::Less);
    // numerator = x·2^64 + r as a 4-limb value (little endian)
    let num = [r[0], x[0].wrapping_add(r[1]), 0u64, 0u64];
    // handle carry from r[1] addition and x limbs
    let mut n = [0u64; 4];
    n[0] = r[0];
    let (s1, c1) = x[0].overflowing_add(r[1]);
    n[1] = s1;
    let (s2, c2) = x[1].overflowing_add(r[2]);
    let (s2b, c2b) = s2.overflowing_add(c1 as u64);
    n[2] = s2b;
    n[3] = x[2].wrapping_add(c2 as u64).wrapping_add(c2b as u64);
    let _ = num;

    // normalize: shift so that the top limb of d has its high bit set
    let dbits = if d[2] != 0 {
        192 - d[2].leading_zeros() as usize
    } else if d[1] != 0 {
        128 - d[1].leading_zeros() as usize
    } else {
        64 - d[0].leading_zeros() as usize
    };
    assert!(dbits > 64, "divisor must exceed 64 bits for this routine");
    let shift = 192 - dbits; // bring divisor top bit to bit 191

    let dn = shl192(d, shift);
    let nn = shl256(n, shift);

    // divisor now occupies limbs dn[1..3] effectively (top bit of dn[2] set
    // when dbits>128, else dn[1]); we do schoolbook with quotient < 2^64.
    // Estimate quotient from top 128 bits of numerator / top 64 bits of divisor.
    let (dtop, ntop, nnext) = if dn[2] != 0 {
        (dn[2], ((nn[3] as u128) << 64) | nn[2] as u128, nn[1])
    } else {
        (dn[1], ((nn[2] as u128) << 64) | nn[1] as u128, nn[0])
    };
    let _ = nnext;
    // Note: the true quotient can be exactly 2^64 (when x is within d/2^64 of
    // d and the rounding term pushes it over); the result is returned mod 2^64
    // which is exactly what decryption mod t = 2^64 needs.
    let mut qhat = (ntop / dtop as u128).min(u64::MAX as u128) as u64;

    // correct the estimate downward (Knuth: est ∈ [q, q+2] after normalization)
    loop {
        let prod = mul192_by_u64(dn, qhat); // 4 limbs
        if cmp256(prod, nn) == std::cmp::Ordering::Greater {
            qhat -= 1;
        } else {
            let rem = sub256(nn, prod);
            if cmp256(rem, [dn[0], dn[1], dn[2], 0]) != std::cmp::Ordering::Less {
                // true quotient was one above the clamp (q = 2^64): wrap
                return qhat.wrapping_add(1);
            }
            break;
        }
    }
    qhat
}

fn shl192(a: U192, s: usize) -> U192 {
    debug_assert!(s < 64 || (s < 128 && a[2] == 0) || s == 0);
    if s == 0 {
        return a;
    }
    if s < 64 {
        [
            a[0] << s,
            (a[1] << s) | (a[0] >> (64 - s)),
            (a[2] << s) | (a[1] >> (64 - s)),
        ]
    } else {
        let s = s - 64;
        if s == 0 {
            [0, a[0], a[1]]
        } else {
            [0, a[0] << s, (a[1] << s) | (a[0] >> (64 - s))]
        }
    }
}

fn shl256(a: [u64; 4], s: usize) -> [u64; 4] {
    if s == 0 {
        return a;
    }
    if s < 64 {
        [
            a[0] << s,
            (a[1] << s) | (a[0] >> (64 - s)),
            (a[2] << s) | (a[1] >> (64 - s)),
            (a[3] << s) | (a[2] >> (64 - s)),
        ]
    } else {
        let b = [0, a[0], a[1], a[2]];
        shl256(b, s - 64)
    }
}

fn mul192_by_u64(a: U192, b: u64) -> [u64; 4] {
    let p0 = a[0] as u128 * b as u128;
    let p1 = a[1] as u128 * b as u128;
    let p2 = a[2] as u128 * b as u128;
    let l0 = p0 as u64;
    let m1 = (p0 >> 64) + (p1 as u64 as u128);
    let l1 = m1 as u64;
    let m2 = (m1 >> 64) + (p1 >> 64) + (p2 as u64 as u128);
    let l2 = m2 as u64;
    let l3 = ((m2 >> 64) + (p2 >> 64)) as u64;
    [l0, l1, l2, l3]
}

fn cmp256(a: [u64; 4], b: [u64; 4]) -> std::cmp::Ordering {
    for i in (0..4).rev() {
        match a[i].cmp(&b[i]) {
            std::cmp::Ordering::Equal => continue,
            o => return o,
        }
    }
    std::cmp::Ordering::Equal
}

fn sub256(a: [u64; 4], b: [u64; 4]) -> [u64; 4] {
    let mut out = [0u64; 4];
    let mut borrow = 0u64;
    for i in 0..4 {
        let (d, b1) = a[i].overflowing_sub(b[i]);
        let (d2, b2) = d.overflowing_sub(borrow);
        out[i] = d2;
        borrow = (b1 as u64) + (b2 as u64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_sub_roundtrip() {
        let a: U192 = [u64::MAX, 5, 1];
        let b: U192 = [1, u64::MAX, 0];
        let s = u192_add(a, b);
        assert_eq!(u192_sub(s, b), a);
        assert_eq!(u192_sub(s, a), b);
    }

    #[test]
    fn mul_u128_u64_matches_small() {
        let a = 123456789012345678901234567890u128;
        let b = 987654321u64;
        let p = mul_u128_u64(a, b);
        // verify against u256 decomposition via splitting a
        let lo = (a as u64) as u128 * b as u128;
        let hi = (a >> 64) * b as u128;
        let expect0 = lo as u64;
        let carry = (lo >> 64) + (hi as u64 as u128);
        assert_eq!(p[0], expect0);
        assert_eq!(p[1], carry as u64);
        assert_eq!(p[2], ((carry >> 64) + (hi >> 64)) as u64);
    }

    /// Reference: floor((x·2^64 + r)/d) via bitwise long division over a
    /// 4-limb numerator, returned mod 2^64.
    fn divround_ref(x: u128, r: u128, d: u128) -> u64 {
        // numerator limbs (little endian): n = x·2^64 + r
        let mut n = [0u64; 4];
        n[0] = r as u64;
        let s1 = (x as u64) as u128 + (r >> 64);
        n[1] = s1 as u64;
        let s2 = (x >> 64) + (s1 >> 64);
        n[2] = s2 as u64;
        n[3] = (s2 >> 64) as u64;
        let mut rem: u128 = 0;
        let mut q: u128 = 0;
        for i in (0..256).rev() {
            let bit = (n[i / 64] >> (i % 64)) & 1;
            rem = (rem << 1) | bit as u128;
            q = q.wrapping_shl(1);
            if rem >= d {
                rem -= d;
                q |= 1;
            }
        }
        q as u64
    }

    #[test]
    fn divround_exact_small_cases() {
        let d_val: u128 = (1u128 << 70) + 3;
        let d = u192_from_u128(d_val);
        for xv in [1u128, 12345, (1 << 69), d_val - 1] {
            let x = u192_from_u128(xv);
            let half = u192_from_u128(d_val / 2);
            let q = divround_shift64(x, half, d);
            assert_eq!(q, divround_ref(xv, d_val / 2, d_val), "x={xv}");
        }
    }

    #[test]
    fn divround_large_divisor() {
        // 120-bit divisor (like a 2-prime q), plus the wrap-around edge
        let q0 = 1152921504606830593u64;
        let q1 = 1152921504606748673u64;
        let d_val = q0 as u128 * q1 as u128;
        let d = u192_from_u128(d_val);
        let half = u192_from_u128(d_val / 2);
        for xv in [1u128, d_val / 2, d_val - 1, d_val - 2, 7 * (d_val / 13)] {
            let x = u192_from_u128(xv);
            let got = divround_shift64(x, half, d);
            assert_eq!(got, divround_ref(xv, d_val / 2, d_val), "x={xv}");
        }
    }

    #[test]
    fn divround_three_prime_modulus() {
        // the actual 180-bit q used by BFV: exercise via random x < q compared
        // against the bitwise reference generalized to a 3-limb divisor
        use crate::he::params::PRIMES;
        let q01 = PRIMES[0] as u128 * PRIMES[1] as u128;
        let q = mul_u128_u64(q01, PRIMES[2]);
        let mut half = q;
        let mut carry = 0u64;
        for limb in half.iter_mut().rev() {
            let v = ((carry as u128) << 64) | *limb as u128;
            *limb = (v >> 1) as u64;
            carry = (v & 1) as u64;
        }
        let mut rng = crate::util::Xoshiro256::seed_from_u64(5);
        for _ in 0..50 {
            // random x < q: sample 3 limbs and reduce
            // keep the sample below 2q so the small-reduction applies
            let x = u192_mod_small([rng.next_u64(), rng.next_u64(), rng.next_u64() % q[2]], q);
            let got = divround_shift64(x, half, q);
            // bitwise reference over limbs
            let mut n = [0u64; 4];
            // n = x<<64 + half
            n[0] = half[0];
            let mut carry2 = 0u128;
            for i in 0..3 {
                let s = x[i] as u128 + if i + 1 < 3 { half[i + 1] as u128 } else { 0 } + carry2;
                n[i + 1] = s as u64;
                carry2 = s >> 64;
            }
            let mut rem = [0u64; 3]; // < q fits 3 limbs
            let mut quot: u128 = 0;
            for i in (0..256).rev() {
                // rem = rem<<1 | bit
                let bit = (n[i / 64] >> (i % 64)) & 1;
                let mut nr = [0u64; 3];
                nr[2] = (rem[2] << 1) | (rem[1] >> 63);
                nr[1] = (rem[1] << 1) | (rem[0] >> 63);
                nr[0] = (rem[0] << 1) | bit;
                rem = nr;
                quot = quot.wrapping_shl(1);
                if u192_geq(rem, q) {
                    rem = u192_sub(rem, q);
                    quot |= 1;
                }
            }
            assert_eq!(got, quot as u64);
        }
    }

    #[test]
    fn mod_small_reduces() {
        let m = u192_from_u128(1000);
        assert_eq!(u192_mod_small(u192_from_u128(2500), m), u192_from_u128(500));
        assert_eq!(u192_mod_small(u192_from_u128(999), m), u192_from_u128(999));
    }
}
