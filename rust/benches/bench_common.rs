//! Shared helpers for the paper-reproduction benches.
//!
//! Scale policy: the paper's testbed runs full-width BERT on a 32-core
//! Threadripper for minutes-to-hours per inference; this repo's benches
//! default to width-reduced proxies (same layer counts, same token counts,
//! dim ≈ 128) so the full table/figure sweep completes in tens of minutes.
//! Token-dependent protocol structure — the quantity every figure compares —
//! is unchanged; `Calibration` (published-anchor κ) transports published
//! numbers onto this substrate where figures need them. Environment knobs:
//!
//!   CP_BENCH_SEQ=32     padded token count (Fig. 9 sweeps its own lengths)
//!   CP_BENCH_HE=4096    BFV ring degree
//!   CP_BENCH_FULL=1     full-width models (hours; for the record runs)

#![allow(dead_code)]

use cipherprune::coordinator::{run_inference, EngineConfig, EngineKind, RunResult};
use cipherprune::net::NetModel;
use cipherprune::nn::{ModelConfig, ModelWeights, ThresholdSchedule, Workload};
use cipherprune::runtime::artifact;

pub fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

pub fn bench_seq() -> usize {
    env_usize("CP_BENCH_SEQ", 32)
}

pub fn bench_he_n() -> usize {
    env_usize("CP_BENCH_HE", 4096)
}

pub fn full_width() -> bool {
    std::env::var("CP_BENCH_FULL").is_ok()
}

/// Width-reduced proxy of a paper model (dim ≈ 128, layer count preserved).
pub fn proxy_config(name: &str) -> ModelConfig {
    let base = ModelConfig::by_name(name).expect("known model");
    if full_width() {
        return base;
    }
    let scale = match name {
        "bert-medium" => 4, // dim 128, 2 heads, 8 layers
        "bert-base" => 6,   // dim 128, 2 heads, 12 layers
        "bert-large" => 8,  // dim 128, 2 heads, 24 layers
        "gpt2-base" => 6,
        _ => 1,
    };
    if scale > 1 { base.scaled(scale) } else { base }
}

/// Salient weights for a proxy config (deterministic; pruning-friendly).
pub fn proxy_weights(cfg: &ModelConfig) -> ModelWeights {
    ModelWeights::salient(cfg, 42)
}

/// Engine config with bench defaults (learned thresholds when present).
pub fn bench_engine(kind: EngineKind, cfg: &ModelConfig) -> EngineConfig {
    let mut ec = EngineConfig::new(kind).he_n(bench_he_n()).iron_segments(16);
    if kind.uses_schedule() {
        // learned thresholds only transfer to the architecture they were
        // trained for; proxies with other layer counts use the default ramp
        if let Some(s) = ThresholdSchedule::load(&artifact("thresholds.json")) {
            if s.theta.len() == cfg.n_layers {
                ec = ec.schedule(s);
            }
        }
    }
    ec
}

/// One measured run on the standard QNLI-like workload (representative
/// sample: real length pinned to the workload mean).
pub fn run_once(kind: EngineKind, cfg: &ModelConfig, w: &ModelWeights, seq: usize, seed: u64) -> RunResult {
    let sample = Workload::qnli_like(cfg, seq).representative(seed);
    run_inference(&bench_engine(kind, cfg), w, &sample.ids)
}

/// Modeled end-to-end time under a network: measured compute + transfer.
pub fn modeled_s(r: &RunResult, net: &NetModel) -> f64 {
    r.wall_s + net.time(&r.total_stats())
}

pub fn gb(bytes: u64) -> f64 {
    bytes as f64 / 1e9
}

/// Table-1 paper numbers for ratio checks (time s, comm GB).
pub fn paper_table1(engine: EngineKind, model: &str) -> Option<(f64, f64)> {
    use cipherprune::baselines::{published, Framework};
    let f = match engine {
        EngineKind::Iron => Framework::Iron,
        EngineKind::BoltNoWe => Framework::BoltNoWe,
        EngineKind::Bolt => Framework::Bolt,
        EngineKind::CipherPrune => {
            return match model {
                "bert-medium" => Some((43.6, 6.7)),
                "bert-base" => Some((79.1, 9.7)),
                "bert-large" => Some((157.6, 18.4)),
                _ => None,
            }
        }
        _ => return None,
    };
    published(f, model)
}

/// Strip a "/wN" width suffix from a proxy config name.
pub fn base_name(cfg: &ModelConfig) -> String {
    cfg.name.split('/').next().unwrap_or(&cfg.name).to_string()
}
