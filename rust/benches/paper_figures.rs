//! Regenerates the paper's figures (as printed series).
//!
//!   cargo bench --bench paper_figures            # all figures
//!   cargo bench --bench paper_figures -- fig11   # one figure
//!
//! Fig. 9   — runtime vs input length on GPT2 (BOLT w/o W.E. / BOLT /
//!            CipherPrune†; polynomial reduction disabled per the paper).
//! Fig. 10  — per-protocol runtime breakdown, LAN vs WAN.
//! Fig. 11  — pruning-protocol comparison: bitonic sort vs separate swaps
//!            vs MSB-bind, over n.
//! Fig. 12  — λ/α ablation: accuracy-latency trade-off via threshold sweeps.
//! Fig. 15  — BumbleBee/IRON/BOLT comparison (1 Gbps LAN), published-anchor
//!            calibrated.
//! Fig. 16/17 — 3PC comparison (MPCFormer, PUMA) on BERT and GPT2.
//! Fig. 19  — per-layer pruned tokens + pruning-protocol runtime.

#[path = "bench_common.rs"]
mod common;

use cipherprune::baselines::bitonic::bitonic_sort_prune;
use cipherprune::baselines::Framework;
use cipherprune::coordinator::{run_inference, EngineKind};
use cipherprune::fixed::{F64Mat, Fix};
use cipherprune::net::NetModel;
use cipherprune::nn::{forward, ForwardOptions, ThresholdSchedule, Workload};
use cipherprune::party::run2_owned_sym;
use cipherprune::protocols::mask::{pi_mask_strategy, MaskStrategy};
use cipherprune::protocols::Engine2P;
use cipherprune::util::bench::{fmt_duration, Table};
use cipherprune::util::Xoshiro256;
use common::*;

fn fig9() {
    println!("\n== Fig. 9: runtime vs input length (GPT2 proxy, LAN-modeled) ==");
    let cfg = proxy_config("gpt2-base");
    let w = proxy_weights(&cfg);
    let seqs: Vec<usize> = std::env::var("CP_FIG9_SEQS")
        .unwrap_or_else(|_| "16,32,64".into())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let mut t = Table::new(
        "LAN-modeled seconds",
        &["tokens", "BOLT w/o W.E.", "BOLT", "CipherPrune†", "speedup"],
    );
    for &seq in &seqs {
        let a = run_once(EngineKind::BoltNoWe, &cfg, &w, seq, 9);
        let b = run_once(EngineKind::Bolt, &cfg, &w, seq, 9);
        let c = run_once(EngineKind::CipherPrunePruneOnly, &cfg, &w, seq, 9);
        let (la, lb, lc) = (
            modeled_s(&a, &NetModel::LAN),
            modeled_s(&b, &NetModel::LAN),
            modeled_s(&c, &NetModel::LAN),
        );
        t.row(vec![
            seq.to_string(),
            format!("{la:.2}"),
            format!("{lb:.2}"),
            format!("{lc:.2}"),
            format!("{:.2}x", la / lc),
        ]);
    }
    t.print();
    println!("(paper: speedup grows with length — 1.9x @32 to 10.6x @512 tokens)");
}

fn fig10() {
    let seq = bench_seq();
    let cfg = proxy_config("bert-base");
    let w = proxy_weights(&cfg);
    println!("\n== Fig. 10: runtime breakdown by protocol ({} @ {seq} tokens) ==", cfg.name);
    for kind in [EngineKind::BoltNoWe, EngineKind::CipherPrune] {
        let r = run_once(kind, &cfg, &w, seq, 10);
        let mut t = Table::new(
            &format!("{}", kind.name()),
            &["protocol", "compute", "comm MB", "LAN net", "WAN net", "% of LAN total"],
        );
        let protos = ["matmul", "softmax", "gelu", "layernorm", "prune", "mask", "reduce", "embed"];
        let lan_total: f64 = modeled_s(&r, &NetModel::LAN);
        for p in protos {
            let s = r.stats_by_prefix(p);
            if s.bytes == 0 && r.wall_by_prefix(p) == 0.0 {
                continue;
            }
            let wall = r.wall_by_prefix(p);
            let lan = NetModel::LAN.time(&s);
            let wan = NetModel::WAN.time(&s);
            t.row(vec![
                p.to_string(),
                fmt_duration(wall),
                format!("{:.1}", s.bytes as f64 / 1e6),
                fmt_duration(lan),
                fmt_duration(wan),
                format!("{:.1}%", (wall + lan) / lan_total * 100.0),
            ]);
        }
        t.print();
        let prune_frac = (r.wall_by_prefix("prune")
            + r.wall_by_prefix("mask")
            + r.wall_by_prefix("reduce"))
            / r.wall_s
            * 100.0;
        println!("pruning protocols: {prune_frac:.1}% of compute (paper: 1.6% of total)\n");
    }
}

fn fig11() {
    println!("\n== Fig. 11: pruning-protocol comparison ==");
    // Progressive pruning removes a small, roughly constant number of
    // tokens per layer (m=8 here), so Π_mask costs O(mn) swaps while
    // W.E.'s bitonic network is O(n log² n) regardless of m. Compute is
    // measured; network time is modeled from the recorded flights —
    // Π_mask's bubble swaps are sequential (each pays a round trip),
    // whereas our bitonic implementation batches per network stage, so
    // the in-memory compute column *under*-states the sort's deployed cost
    // relative to the paper (which reports both unbatched).
    let fix = Fix::default();
    let d = 64;
    let mut t = Table::new(
        "prune m=8 tokens out of n",
        &["n", "protocol", "compute", "swaps", "flights", "LAN total", "WAN total"],
    );
    for n in [32usize, 64, 128, 256] {
        let m = 8.min(n / 4);
        let keep = n - m;
        // shared inputs: scores make the last m tokens the least important
        let x = F64Mat::from_vec(n, d, (0..n * d).map(|i| (i % 17) as f64 * 0.1).collect());
        let scores: Vec<f64> = (0..n).map(|i| if i < keep { 0.5 + (i % 7) as f64 * 0.01 } else { 0.01 }).collect();
        let mask: Vec<u8> = (0..n).map(|i| (i < keep) as u8).collect();
        for variant in 0..4 {
            let x2 = x.clone();
            let scores2 = scores.clone();
            let mask2 = mask.clone();
            let t0 = std::time::Instant::now();
            let ((swaps, stats), _, _) = run2_owned_sym(77 + n as u64 + variant, move |ctx| {
                let mut e = Engine2P::new(ctx, cipherprune::gates::TripleMode::Ot, 128, fix);
                // share inputs deterministically
                let mut rng = Xoshiro256::seed_from_u64(5);
                let ring = x2.to_ring(fix);
                let r: Vec<u64> = (0..ring.data.len()).map(|_| rng.next_u64()).collect();
                let xs = if e.is_p0() {
                    cipherprune::fixed::RingMat::from_vec(
                        n, d,
                        ring.data.iter().zip(&r).map(|(a, b)| a.wrapping_sub(*b)).collect())
                } else {
                    cipherprune::fixed::RingMat::from_vec(n, d, r)
                };
                let sc: Vec<u64> = if e.is_p0() {
                    scores2.iter().map(|&v| fix.enc(v)).collect()
                } else {
                    vec![0u64; n]
                };
                let swaps = match variant {
                    0 => bitonic_sort_prune(&mut e, &xs, &sc, keep).swaps,
                    v => {
                        let mut prg = e.mpc.ctx.dealer_prg("fig11-mask");
                        let rb: Vec<u8> =
                            (0..n).map(|_| (prg.next_u64() & 1) as u8).collect();
                        let ms: Vec<u8> = if e.is_p0() {
                            mask2.iter().zip(&rb).map(|(m, x)| m ^ x).collect()
                        } else {
                            rb
                        };
                        let strat = match v {
                            1 => MaskStrategy::SeparateSwap,
                            2 => MaskStrategy::MsbBind,
                            _ => MaskStrategy::BatchedPrefix,
                        };
                        pi_mask_strategy(&mut e, &xs, &sc, &ms, strat).swaps
                    }
                };
                (swaps, e.mpc.ctx.ch.total_stats())
            });
            let el = t0.elapsed().as_secs_f64();
            let name = ["bitonic sort", "separate swap", "MSB-bind", "batched prefix (ours)"]
                [variant as usize];
            t.row(vec![
                n.to_string(),
                name.to_string(),
                fmt_duration(el),
                swaps.to_string(),
                stats.flights.to_string(),
                fmt_duration(el + NetModel::LAN.time(&stats)),
                fmt_duration(el + NetModel::WAN.time(&stats)),
            ]);
        }
    }
    t.print();
    println!("(paper: MSB-bind beats bitonic sort by 2.2–20.3x, growing with n — the");
    println!(" asymptotic O(mn) vs O(n log² n) separation shows in the swap counts)");
}

fn fig12() {
    println!("\n== Fig. 12: λ/α ablation — accuracy vs latency via threshold sweeps ==");
    // λ ↔ pruning threshold scale; α ↔ reduction threshold scale. Larger
    // values prune/reduce more: latency falls, accuracy eventually drops.
    // Accuracy requires a *trained* model: use the Algorithm 1 artifacts
    // (tiny config) when present, salient weights otherwise.
    let (cfg, w) = match cipherprune::nn::ModelWeights::load(
        &cipherprune::runtime::artifact("weights.bin"),
    ) {
        Ok(w) => (w.config.clone(), w),
        Err(_) => {
            let cfg = proxy_config("bert-base");
            let w = proxy_weights(&cfg);
            (cfg, w)
        }
    };
    let seq = bench_seq().min(cfg.max_seq);
    let wl = Workload::qnli_like(&cfg, seq);
    let eval_batch = wl.batch(64, 120);
    let mut t = Table::new(
        "threshold sweep around the learned schedule (proxy for λ/α)",
        &["θ scale", "β scale", "accuracy", "latency (LAN)", "kept@last", "high@last"],
    );
    // base = the Algorithm 1 schedule when it matches this architecture
    let base = cipherprune::nn::ThresholdSchedule::load(
        &cipherprune::runtime::artifact("thresholds.json"),
    )
    .filter(|s| s.theta.len() == cfg.n_layers)
    .unwrap_or_else(|| ThresholdSchedule::default_for(cfg.n_layers));
    for &(ts, bs) in &[(0.0, 1.0), (0.25, 1.0), (1.0, 1.0), (1.0, 0.25), (2.0, 1.0), (4.0, 1.0)] {
        let mut sched = base.clone();
        sched.theta.iter_mut().for_each(|v| *v *= ts);
        sched.beta.iter_mut().for_each(|v| *v *= bs);
        // keep the β > θ invariant
        for (b, &th) in sched.beta.iter_mut().zip(&sched.theta) {
            *b = b.max(th * 1.05);
        }
        // accuracy via the plaintext reference over the eval batch
        let opts = ForwardOptions::cipherprune(sched.clone(), true);
        let correct = eval_batch
            .iter()
            .filter(|s| forward(&w, &s.ids, &opts).predicted() == s.label)
            .count();
        // latency via one private run on the 12-layer proxy (tiny models
        // are overhead-dominated; the proxy shows the real latency axis)
        let pcfg = proxy_config("bert-base");
        let pw = proxy_weights(&pcfg);
        let mut psched = ThresholdSchedule::default_for(pcfg.n_layers);
        psched.theta.iter_mut().for_each(|v| *v *= ts);
        psched.beta.iter_mut().for_each(|v| *v *= bs);
        for (b, &th) in psched.beta.iter_mut().zip(&psched.theta) {
            *b = b.max(th * 1.05);
        }
        let mut ec = bench_engine(EngineKind::CipherPrune, &pcfg);
        ec.schedule = Some(psched);
        let r = run_inference(
            &ec,
            &pw,
            &Workload::qnli_like(&pcfg, bench_seq()).batch(1, 121)[0].ids,
        );
        t.row(vec![
            format!("{ts}"),
            format!("{bs}"),
            format!("{:.3}", correct as f64 / eval_batch.len() as f64),
            fmt_duration(modeled_s(&r, &NetModel::LAN)),
            r.layer_stats.last().map(|s| s.n_kept).unwrap_or(0).to_string(),
            r.layer_stats.last().map(|s| s.n_high).unwrap_or(0).to_string(),
        ]);
    }
    t.print();
    println!("(paper: larger λ/α → faster but eventually less accurate; reduction is gentler than pruning)");
}

fn fig15_16_17() {
    let seq = bench_seq();
    println!("\n== Figs. 15–17: cross-framework comparison ==");
    // Appendix D ports CipherPrune's protocols ONTO each framework (its
    // pruning composes with any 2PC/3PC backend built on comparison + B2A),
    // so the reproduced quantity is the *pruning speedup factor* applied to
    // each framework's published time: we measure
    //   speedup = t(BOLT w/o W.E.) / t(CipherPrune)     (same workload)
    // on our substrate and report published(F) / speedup as the
    // "CipherPrune-on-F" bar, next to published(F) transported by κ for
    // scale context.
    let mut t = Table::new(
        "published baseline vs CipherPrune-on-framework (seconds)",
        &[
            "model", "speedup (ours)", "BumbleBee", "CP-on-BB", "MPCFormer", "CP-on-MF",
            "PUMA", "CP-on-PUMA",
        ],
    );
    for model in ["bert-medium", "bert-base", "bert-large", "gpt2-base"] {
        let cfg = proxy_config(model);
        let w = proxy_weights(&cfg);
        let anchor = run_once(EngineKind::BoltNoWe, &cfg, &w, seq, 15);
        let kind = if model.starts_with("gpt2") {
            EngineKind::CipherPrunePruneOnly // Fig. 17: no polynomial reduction
        } else {
            EngineKind::CipherPrune
        };
        let ours = run_once(kind, &cfg, &w, seq, 15);
        let speedup = modeled_s(&anchor, &NetModel::LAN) / modeled_s(&ours, &NetModel::LAN);
        let cell = |f: Framework| -> (String, String) {
            match cipherprune::baselines::published(f, model) {
                Some((s, _)) => (format!("{s:.1}"), format!("{:.1}", s / speedup)),
                None => ("—".into(), "—".into()),
            }
        };
        let bb = cell(Framework::BumbleBee);
        let mf = cell(Framework::MpcFormer);
        let pu = cell(Framework::Puma);
        t.row(vec![
            model.to_string(),
            format!("{speedup:.2}x"),
            bb.0,
            bb.1,
            mf.0,
            mf.1,
            pu.0,
            pu.1,
        ]);
    }
    t.print();
    println!("(baseline columns are published seconds in each paper's own setting; CP-on-F");
    println!(" divides by our measured pruning speedup. Paper's claims — ≥4.3x vs BumbleBee,");
    println!(" 6.6–9.4x vs MPCFormer, 2.8–4.6x vs PUMA — correspond to the speedup column");
    println!(" at 128–512-token inputs; it grows with CP_BENCH_SEQ.)");
}

fn fig19() {
    let seq = bench_seq().max(32);
    let cfg = proxy_config("bert-base");
    let w = proxy_weights(&cfg);
    let n_samples = env_usize("CP_FIG19_SAMPLES", 4);
    println!(
        "\n== Fig. 19: per-layer pruning profile ({} @ {seq} tokens, {n_samples} QNLI-like samples) ==",
        cfg.name
    );
    let wl = Workload::qnli_like(&cfg, seq);
    let mut pruned = vec![0.0f64; cfg.n_layers];
    let mut times = vec![0.0f64; cfg.n_layers];
    for (i, s) in wl.batch(n_samples, 190).iter().enumerate() {
        let ec = bench_engine(EngineKind::CipherPrune, &cfg);
        let r = run_inference(&ec, &w, &s.ids);
        for (li, st) in r.layer_stats.iter().enumerate() {
            pruned[li] += (st.n_in - st.n_kept) as f64;
            times[li] += st.prune_wall_s;
        }
        let _ = i;
    }
    let mut t = Table::new(
        "mean per-layer pruning",
        &["layer", "pruned tokens", "prune-protocol time"],
    );
    for li in 0..cfg.n_layers {
        t.row(vec![
            li.to_string(),
            format!("{:.1}", pruned[li] / n_samples as f64),
            fmt_duration(times[li] / n_samples as f64),
        ]);
    }
    t.print();
    println!("(paper: padding dominates layer-0 pruning; later layers prune fewer tokens, faster)");
}

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--")) // cargo bench passes --bench
        .collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a.contains(name));
    if want("fig9") {
        fig9();
    }
    if want("fig10") {
        fig10();
    }
    if want("fig11") {
        fig11();
    }
    if want("fig12") {
        fig12();
    }
    if want("fig15") || want("fig16") || want("fig17") {
        fig15_16_17();
    }
    if want("fig19") {
        fig19();
    }
}
