//! Protocol micro-benches — the paper's §3.2/Appendix A runtime claims and
//! the design-choice ablations DESIGN.md calls out.
//!
//!   cargo bench --bench protocols              # everything
//!   cargo bench --bench protocols -- mask      # one group
//!
//! Groups: score (importance-score ASS compute), cmp (Π_CMP amortized),
//! mask (Π_mask per-layer vs bitonic sort), triples (dealer vs OT),
//! fixedpoint (scale sweep accuracy).

#[path = "bench_common.rs"]
mod common;

use cipherprune::baselines::bitonic::bitonic_sort_prune;
use cipherprune::fixed::{F64Mat, Fix, RingMat};
use cipherprune::gates::TripleMode;
use cipherprune::party::run2_owned_sym;
use cipherprune::protocols::gelu::{gelu_ref, pi_gelu, GeluKind};
use cipherprune::protocols::mask::{pi_mask_strategy, MaskStrategy};
use cipherprune::protocols::softmax::importance_scores;
use cipherprune::protocols::Engine2P;
use cipherprune::util::bench::{bench, fmt_duration, Table};
use cipherprune::util::Xoshiro256;
use common::env_usize;

fn share_mat_det(x: &F64Mat, fix: Fix, p0: bool, seed: u64) -> RingMat {
    let ring = x.to_ring(fix);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let r: Vec<u64> = (0..ring.data.len()).map(|_| rng.next_u64()).collect();
    if p0 {
        RingMat::from_vec(
            x.rows,
            x.cols,
            ring.data.iter().zip(&r).map(|(a, b)| a.wrapping_sub(*b)).collect(),
        )
    } else {
        RingMat::from_vec(x.rows, x.cols, r)
    }
}

/// §3.2: "importance score … only 0.1 ms per attention module" — pure
/// local ASS arithmetic, no traffic.
fn bench_score() {
    println!("\n== importance score (Eq. 1, local ASS) ==");
    let fix = Fix::default();
    let mut t = Table::new("per attention module", &["n", "heads", "time"]);
    for (n, h) in [(128usize, 12usize), (128, 24), (512, 12)] {
        let atts: Vec<RingMat> = (0..h)
            .map(|i| {
                let m = F64Mat::from_vec(
                    n,
                    n,
                    (0..n * n).map(|j| ((i + j) % 13) as f64 / 13.0 / n as f64).collect(),
                );
                share_mat_det(&m, fix, true, i as u64)
            })
            .collect();
        // local computation only: run on a single engine-free path by
        // measuring inside one party of a 2P session
        let atts2 = atts.clone();
        let (el, _, _) = run2_owned_sym(40, move |ctx| {
            let mut e = Engine2P::new(ctx, TripleMode::Dealer, 128, fix);
            let t0 = std::time::Instant::now();
            let s = importance_scores(&mut e, &atts2);
            std::hint::black_box(s);
            t0.elapsed().as_secs_f64()
        });
        t.row(vec![n.to_string(), h.to_string(), fmt_duration(el)]);
    }
    t.print();
    println!("(paper: ~0.1 ms per module — ours is local share arithmetic plus one trunc)");
}

/// §3.2: "n invocations of Π_CMP, each within 5 ms" — ours batches, so we
/// report amortized per-comparison cost.
fn bench_cmp() {
    println!("\n== Π_CMP (batched millionaires) ==");
    let fix = Fix::default();
    let mut t = Table::new("batch compare vs threshold", &["batch n", "total", "per cmp"]);
    for n in [128usize, 512, 2048] {
        let xs: Vec<f64> = (0..n).map(|i| (i as f64) / n as f64 - 0.3).collect();
        let (el, _, _) = run2_owned_sym(41, move |ctx| {
            let mut e = Engine2P::new(ctx, TripleMode::Ot, 128, fix);
            let shares: Vec<u64> = if e.is_p0() {
                xs.iter().map(|&v| e.fix.enc(v)).collect()
            } else {
                vec![0u64; xs.len()]
            };
            let t0 = std::time::Instant::now();
            let m = e.mpc.cmp_gt_const(&shares, e.fix.enc(0.1));
            std::hint::black_box(m);
            t0.elapsed().as_secs_f64()
        });
        t.row(vec![
            n.to_string(),
            fmt_duration(el),
            fmt_duration(el / n as f64),
        ]);
    }
    t.print();
    println!("(paper: ≤5 ms per invocation, unbatched; batching amortizes far below that)");
}

/// Appendix A: Π_mask swap strategy vs oblivious sort per layer
/// (paper: swap ≈0.5 s, sort 3.8–4.5 s at BERT-Base/128).
fn bench_mask() {
    println!("\n== Π_mask per layer: swap strategies vs bitonic sort ==");
    let fix = Fix::default();
    let n = env_usize("CP_MASK_N", 128);
    let d = env_usize("CP_MASK_D", 64);
    let m = n / 16; // progressive pruning removes few tokens per layer
    let keep = n - m;
    let x = F64Mat::from_vec(n, d, (0..n * d).map(|i| (i % 23) as f64 * 0.05).collect());
    let mask: Vec<u8> = (0..n).map(|i| (i < keep) as u8).collect();
    let mut t = Table::new(
        &format!("prune {m}/{n} tokens (d={d})"),
        &["protocol", "time", "swaps"],
    );
    for variant in ["msb-bind", "separate", "bitonic"] {
        let x2 = x.clone();
        let mask2 = mask.clone();
        let v = variant;
        let t0 = std::time::Instant::now();
        let (swaps, _, _) = run2_owned_sym(42, move |ctx| {
            let mut e = Engine2P::new(ctx, TripleMode::Ot, 128, fix);
            let xs = share_mat_det(&x2, fix, e.is_p0(), 7);
            let sc: Vec<u64> = if e.is_p0() {
                (0..n).map(|i| e.fix.enc(if mask2[i] == 1 { 0.5 } else { 0.01 })).collect()
            } else {
                vec![0u64; n]
            };
            match v {
                "bitonic" => bitonic_sort_prune(&mut e, &xs, &sc, keep).swaps,
                _ => {
                    let mut prg = e.mpc.ctx.dealer_prg("bench-mask");
                    let rb: Vec<u8> = (0..n).map(|_| (prg.next_u64() & 1) as u8).collect();
                    let ms: Vec<u8> = if e.is_p0() {
                        mask2.iter().zip(&rb).map(|(m, x)| m ^ x).collect()
                    } else {
                        rb
                    };
                    let strat = if v == "separate" {
                        MaskStrategy::SeparateSwap
                    } else {
                        MaskStrategy::MsbBind
                    };
                    pi_mask_strategy(&mut e, &xs, &sc, &ms, strat).swaps
                }
            }
        });
        t.row(vec![variant.to_string(), fmt_duration(t0.elapsed().as_secs_f64()), swaps.to_string()]);
    }
    t.print();
    println!("(paper: swap 0.5 s vs sort 3.8–4.5 s per BERT-Base layer; ratios are the claim)");
}

/// DESIGN.md ablation: dealer-provided vs OT-generated Beaver triples.
fn bench_triples() {
    println!("\n== Beaver triples: dealer vs OT generation ==");
    let fix = Fix::default();
    let n = 10_000usize;
    let mut t = Table::new(&format!("{n} triples"), &["mode", "time", "traffic MB"]);
    for mode in [TripleMode::Dealer, TripleMode::Ot] {
        let t0 = std::time::Instant::now();
        let (bytes, _, _) = run2_owned_sym(43, move |ctx| {
            let mut e = Engine2P::new(ctx, mode, 128, fix);
            let before = e.mpc.ctx.ch.total_stats().bytes;
            let tr = e.mpc.triples(n);
            std::hint::black_box(tr);
            e.mpc.ctx.ch.total_stats().bytes - before
        });
        t.row(vec![
            format!("{mode:?}"),
            fmt_duration(t0.elapsed().as_secs_f64()),
            format!("{:.2}", bytes as f64 / 1e6),
        ]);
    }
    t.print();
}

/// DESIGN.md ablation: fixed-point fraction bits vs protocol accuracy.
fn bench_fixedpoint() {
    println!("\n== fixed-point scale sweep: Π_GELU accuracy vs f ==");
    let mut t = Table::new("max |err| vs f64 reference", &["frac bits", "max err", "mean err"]);
    for f in [8u32, 12, 16] {
        let fix = Fix { frac_bits: f };
        let xs: Vec<f64> = (0..256).map(|i| -6.0 + 12.0 * i as f64 / 255.0).collect();
        let xs2 = xs.clone();
        let (out, _, _) = run2_owned_sym(44 + f as u64, move |ctx| {
            let mut e = Engine2P::new(ctx, TripleMode::Ot, 128, fix);
            let shares: Vec<u64> = if e.is_p0() {
                xs2.iter().map(|&v| e.fix.enc(v)).collect()
            } else {
                vec![0u64; xs2.len()]
            };
            let y = pi_gelu(&mut e, &shares, GeluKind::High);
            e.mpc.open(&y).iter().map(|&v| e.fix.dec(v)).collect::<Vec<f64>>()
        });
        let (mut mx, mut sum) = (0.0f64, 0.0f64);
        for (i, &x) in xs.iter().enumerate() {
            let err = (out[i] - gelu_ref(x, GeluKind::High)).abs();
            mx = mx.max(err);
            sum += err;
        }
        t.row(vec![
            f.to_string(),
            format!("{mx:.5}"),
            format!("{:.5}", sum / xs.len() as f64),
        ]);
    }
    t.print();
    println!("(f=12 is the default: error well below the approximation error of Eq. 7 itself)");
}

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--")) // cargo bench passes --bench
        .collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a.contains(name));
    let _ = bench("noop", 0, 1, || {}); // keep util::bench linked/used
    if want("score") {
        bench_score();
    }
    if want("cmp") {
        bench_cmp();
    }
    if want("mask") {
        bench_mask();
    }
    if want("triples") {
        bench_triples();
    }
    if want("fixedpoint") {
        bench_fixedpoint();
    }
}
