//! Regenerates the paper's tables.
//!
//!   cargo bench --bench paper_tables            # all tables
//!   cargo bench --bench paper_tables -- table1  # one table
//!
//! Table 1  — end-to-end time/comm for IRON / BOLT w/o W.E. / BOLT /
//!            CipherPrune on BERT-{Medium,Base,Large} proxies @ 128-token
//!            workloads (CP_BENCH_SEQ tokens by default; see bench_common).
//! Table 2  — per-task accuracy (from Algorithm 1's train_report.json) +
//!            measured time of the four methods.
//! Table 3  — per-layer SoftMax/GELU communication, pruned vs unpruned.

#[path = "bench_common.rs"]
mod common;

use cipherprune::coordinator::EngineKind;
use cipherprune::net::NetModel;
use cipherprune::runtime::artifact;
use cipherprune::util::bench::{fmt_duration, Table};
use cipherprune::util::json::Json;
use common::*;

fn table1() {
    let seq = bench_seq();
    println!("\n== Table 1: end-to-end comparison (proxy width, {seq} tokens, LAN-modeled) ==");
    let engines = [
        EngineKind::Iron,
        EngineKind::BoltNoWe,
        EngineKind::Bolt,
        EngineKind::CipherPrune,
    ];
    for model in ["bert-medium", "bert-base", "bert-large"] {
        let cfg = proxy_config(model);
        let w = proxy_weights(&cfg);
        let mut t = Table::new(
            &format!("{model} (proxy {})", cfg.name),
            &["method", "time", "comm MB", "LAN total", "speedup", "paper speedup"],
        );
        let mut base: Option<f64> = None; // BOLT w/o W.E. anchor
        let paper_base = paper_table1(EngineKind::BoltNoWe, model).map(|(s, _)| s);
        for kind in engines {
            let r = run_once(kind, &cfg, &w, seq, 1);
            let lan = modeled_s(&r, &NetModel::LAN);
            if kind == EngineKind::BoltNoWe {
                base = Some(lan);
            }
            let speedup = base.map(|b| format!("{:.2}x", b / lan)).unwrap_or_default();
            let paper = match (paper_table1(kind, model), paper_base) {
                (Some((ps, _)), Some(pb)) => format!("{:.2}x", pb / ps),
                _ => String::new(),
            };
            t.row(vec![
                kind.name().to_string(),
                fmt_duration(r.wall_s),
                format!("{:.1}", r.total_stats().bytes as f64 / 1e6),
                fmt_duration(lan),
                speedup,
                paper,
            ]);
        }
        t.print();
    }
    println!("(speedups are relative to BOLT w/o W.E.; paper column from Table 1 of the paper)");
}

fn table2() {
    println!("\n== Table 2: accuracy (Algorithm 1) and method time ==");
    // accuracy from the python training report
    let report = std::fs::read_to_string(artifact("train_report.json")).ok();
    match report.and_then(|s| Json::parse(&s).ok()) {
        Some(j) => {
            let mut t = Table::new("accuracy per task (synthetic GLUE substitutes)",
                                   &["task", "accuracy", "kept/layer (last round)"]);
            for task in ["mnli", "qnli", "sst2", "mrpc"] {
                if let Some(r) = j.get(task) {
                    let acc = r.get("accuracy").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    let kept = r
                        .get("rounds")
                        .and_then(|v| v.as_arr())
                        .and_then(|a| a.last())
                        .and_then(|r| r.get("kept_per_layer"))
                        .and_then(|v| v.as_f64_vec())
                        .map(|v| format!("{v:.1?}"))
                        .unwrap_or_default();
                    t.row(vec![task.to_string(), format!("{:.3}", acc), kept]);
                }
            }
            t.print();
        }
        None => println!("  (no artifacts/train_report.json — run `make train` for accuracy rows)"),
    }
    // method time on the BERT-Base proxy
    let seq = bench_seq();
    let cfg = proxy_config("bert-base");
    let w = proxy_weights(&cfg);
    let mut t = Table::new(
        &format!("method time ({} @ {seq} tokens, LAN-modeled)", cfg.name),
        &["method", "time", "LAN total", "kept@last"],
    );
    for kind in [
        EngineKind::BoltNoWe,
        EngineKind::Bolt,
        EngineKind::CipherPrunePruneOnly,
        EngineKind::CipherPrune,
    ] {
        let r = run_once(kind, &cfg, &w, seq, 2);
        t.row(vec![
            kind.name().to_string(),
            fmt_duration(r.wall_s),
            fmt_duration(modeled_s(&r, &NetModel::LAN)),
            r.layer_stats.last().map(|s| s.n_kept).unwrap_or(0).to_string(),
        ]);
    }
    t.print();
}

fn table3() {
    let seq = bench_seq();
    let cfg = proxy_config("bert-base");
    let w = proxy_weights(&cfg);
    println!("\n== Table 3: per-layer SoftMax/GELU comm (MB), {} @ {seq} tokens ==", cfg.name);
    let unpruned = run_once(EngineKind::BoltNoWe, &cfg, &w, seq, 3);
    let pruned = run_once(EngineKind::CipherPrune, &cfg, &w, seq, 3);
    let mut t = Table::new(
        "communication per layer",
        &["layer", "softmax", "pruned softmax", "gelu", "pruned gelu", "tokens kept"],
    );
    for li in 0..cfg.n_layers {
        let u = &unpruned.layer_stats[li];
        let p = &pruned.layer_stats[li];
        t.row(vec![
            li.to_string(),
            format!("{:.2}", u.softmax_bytes as f64 / 1e6),
            format!("{:.2}", p.softmax_bytes as f64 / 1e6),
            format!("{:.2}", u.gelu_bytes as f64 / 1e6),
            format!("{:.2}", p.gelu_bytes as f64 / 1e6),
            p.n_kept.to_string(),
        ]);
    }
    t.print();
    println!("(paper Table 3 shape: pruned columns decay layer-by-layer; unpruned stay flat)");
}

fn main() {
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with("--")) // cargo bench passes --bench
        .collect();
    let want = |name: &str| args.is_empty() || args.iter().any(|a| a.contains(name));
    if want("table1") {
        table1();
    }
    if want("table2") {
        table2();
    }
    if want("table3") {
        table3();
    }
}
