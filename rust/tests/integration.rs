//! Cross-layer integration tests: artifacts produced by `make artifacts`
//! (python AOT) consumed by the Rust runtime and protocol engines.
//!
//! These tests skip gracefully when `artifacts/` has not been built so that
//! `cargo test` works on a fresh checkout; `make test` always builds
//! artifacts first.

use std::path::Path;

use cipherprune::coordinator::{run_inference, EngineConfig, EngineKind};
use cipherprune::nn::{
    forward, Activations, ForwardOptions, ModelWeights, PruneStrategy, ThresholdSchedule,
};
use cipherprune::protocols::gelu::GeluKind;
use cipherprune::runtime::{artifact, TensorF32, XlaRuntime};

fn artifacts_ready() -> bool {
    artifact("model.hlo.txt").exists() && artifact("weights.bin").exists()
}

/// The headline three-layer consistency check: the XLA-compiled JAX model
/// (Pallas kernels inlined) must agree with the Rust plaintext reference on
/// the weights exported by python.
#[test]
fn xla_oracle_matches_rust_reference() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let w = ModelWeights::load(&artifact("weights.bin")).expect("CPW1 weights");
    let meta = std::fs::read_to_string(artifact("meta.json")).unwrap();
    let meta = cipherprune::util::json::Json::parse(&meta).unwrap();
    let seq = meta.get("seq_len").and_then(|v| v.as_usize()).unwrap();
    let vocab = w.config.vocab;

    // deterministic input
    let ids: Vec<usize> = (0..seq).map(|i| (i * 7 + 3) % vocab).collect();
    let mut onehot = vec![0f32; seq * vocab];
    for (i, &id) in ids.iter().enumerate() {
        onehot[i * vocab + id] = 1.0;
    }

    let mut rt = match XlaRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return;
        }
    };
    let out = rt
        .run_f32(
            &artifact("model.hlo.txt"),
            &[TensorF32::new(onehot, vec![seq as i64, vocab as i64])],
        )
        .expect("XLA execution");
    let xla_logits = &out[0].data;

    let opts = ForwardOptions {
        prune: PruneStrategy::None,
        reduce: false,
        activations: Activations::Polynomial { gelu_high: GeluKind::High },
    };
    let ref_out = forward(&w, &ids, &opts);
    assert_eq!(xla_logits.len(), ref_out.logits.len());
    for (x, r) in xla_logits.iter().zip(&ref_out.logits) {
        assert!(
            (*x as f64 - r).abs() < 5e-3,
            "XLA {xla_logits:?} vs reference {:?}",
            ref_out.logits
        );
    }
}

/// The standalone importance-kernel artifact must match Eq. 1.
#[test]
fn importance_kernel_artifact_matches_eq1() {
    if !artifact("importance.hlo.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let meta = std::fs::read_to_string(artifact("meta.json")).unwrap();
    let meta = cipherprune::util::json::Json::parse(&meta).unwrap();
    let seq = meta.get("seq_len").and_then(|v| v.as_usize()).unwrap();
    let heads = 2usize; // tiny config
    let mut att = vec![0f32; heads * seq * seq];
    // row-stochastic random-ish attention
    for h in 0..heads {
        for i in 0..seq {
            let mut row: Vec<f32> =
                (0..seq).map(|j| ((h * 31 + i * 7 + j * 3) % 11) as f32 + 1.0).collect();
            let s: f32 = row.iter().sum();
            row.iter_mut().for_each(|v| *v /= s);
            for (j, &v) in row.iter().enumerate() {
                att[h * seq * seq + i * seq + j] = v;
            }
        }
    }
    let mut rt = match XlaRuntime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("skipping: {e:#}");
            return;
        }
    };
    let out = rt
        .run_f32(
            &artifact("importance.hlo.txt"),
            &[TensorF32::new(att.clone(), vec![heads as i64, seq as i64, seq as i64])],
        )
        .unwrap();
    // Eq. 1 reference
    for i in 0..seq {
        let mut s = 0.0f64;
        for h in 0..heads {
            for j in 0..seq {
                s += att[h * seq * seq + j * seq + i] as f64;
            }
        }
        s /= (heads * seq) as f64;
        assert!(
            (out[0].data[i] as f64 - s).abs() < 1e-5,
            "token {i}: kernel {} vs eq1 {s}",
            out[0].data[i]
        );
    }
}

/// The full CipherPrune engine runs on python-trained weights + thresholds.
#[test]
fn cipherprune_engine_runs_on_exported_weights() {
    if !artifacts_ready() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let w = ModelWeights::load(&artifact("weights.bin")).unwrap();
    let sched = ThresholdSchedule::load(&artifact("thresholds.json"))
        .unwrap_or_else(|| ThresholdSchedule::default_for(w.config.n_layers))
        .fit_layers(w.config.n_layers);
    let cfg = EngineConfig::for_tests(EngineKind::CipherPrune).schedule(sched.clone());
    let ids: Vec<usize> = (0..8).map(|i| (i * 5 + 1) % w.config.vocab).collect();
    let run = run_inference(&cfg, &w, &ids);
    let want = forward(&w, &ids, &ForwardOptions::cipherprune(sched, true));
    for (g, r) in run.logits.iter().zip(&want.logits) {
        assert!((g - r).abs() < 0.3, "{:?} vs {:?}", run.logits, want.logits);
    }
    for (ls, tr) in run.layer_stats.iter().zip(&want.traces) {
        assert_eq!(ls.n_kept, tr.n_kept);
    }
}
