//! Property-based tests over coordinator and protocol invariants
//! (seeded random cases via util::propcheck; proptest is unavailable
//! offline — failures replay deterministically from the reported seed).

use std::sync::Arc;
use std::time::{Duration, Instant};

use cipherprune::coordinator::batcher::{bucket_for, Batch, BatchPolicy, Batcher};
use cipherprune::coordinator::{EngineKind, InferenceRequest, Router, RouterConfig};
use cipherprune::fixed::{F64Mat, Fix, RingMat};
use cipherprune::net::TransportSpec;
use cipherprune::nn::reference::prune_order;
use cipherprune::nn::{ModelConfig, ModelWeights, ThresholdSchedule, Workload};
use cipherprune::util::{gen_range, propcheck, Xoshiro256};

// ---------------------------------------------------------------- batcher

#[test]
fn batcher_never_loses_or_duplicates_requests() {
    propcheck(
        "batcher-conservation",
        60,
        |rng| {
            let n = gen_range(rng, 1, 40);
            let lens: Vec<usize> = (0..n).map(|_| gen_range(rng, 1, 512)).collect();
            let max_batch = gen_range(rng, 1, 9);
            (lens, max_batch)
        },
        |(lens, max_batch)| {
            let policy = BatchPolicy {
                max_batch: *max_batch,
                linger: Duration::from_millis(0),
                min_bucket: 16,
                max_tokens: 512,
            };
            let mut b = Batcher::new(policy);
            for (i, &l) in lens.iter().enumerate() {
                b.push(InferenceRequest::new(i as u64, vec![1; l], EngineKind::CipherPrune))
                    .map_err(|_| format!("rejected legal len {l}"))?;
            }
            let mut seen = vec![false; lens.len()];
            let mut batches: Vec<Batch> = Vec::new();
            while let Some(batch) = b.next_batch(Instant::now()) {
                batches.push(batch);
            }
            batches.extend(b.drain_all());
            for batch in &batches {
                if batch.requests.len() > *max_batch {
                    return Err(format!("batch over max: {}", batch.requests.len()));
                }
                for r in &batch.requests {
                    if seen[r.id as usize] {
                        return Err(format!("request {} duplicated", r.id));
                    }
                    seen[r.id as usize] = true;
                    let bucket = bucket_for(r.ids.len(), &policy);
                    if bucket != batch.bucket {
                        return Err(format!(
                            "request len {} (bucket {bucket}) in batch bucket {}",
                            r.ids.len(),
                            batch.bucket
                        ));
                    }
                    if r.ids.len() > batch.bucket {
                        return Err("request longer than its bucket".into());
                    }
                }
            }
            if !seen.iter().all(|&s| s) {
                return Err("request lost".into());
            }
            if b.pending() != 0 {
                return Err("pending after drain".into());
            }
            Ok(())
        },
    );
}

#[test]
fn batcher_preserves_fifo_within_bucket() {
    propcheck(
        "batcher-fifo",
        40,
        |rng| {
            let n = gen_range(rng, 2, 30);
            (0..n).map(|_| gen_range(rng, 20, 31)).collect::<Vec<_>>() // one bucket (32)
        },
        |lens| {
            let policy = BatchPolicy {
                max_batch: 4,
                linger: Duration::from_millis(0),
                min_bucket: 16,
                max_tokens: 512,
            };
            let mut b = Batcher::new(policy);
            for (i, &l) in lens.iter().enumerate() {
                b.push(InferenceRequest::new(i as u64, vec![1; l], EngineKind::Bolt)).unwrap();
            }
            let mut last = None;
            while let Some(batch) = b.next_batch(Instant::now()) {
                for r in &batch.requests {
                    if let Some(prev) = last {
                        if r.id <= prev {
                            return Err(format!("order violated: {} after {prev}", r.id));
                        }
                    }
                    last = Some(r.id);
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- pruning

#[test]
fn prune_order_is_stable_partition_permutation() {
    propcheck(
        "prune-order",
        200,
        |rng| {
            let n = gen_range(rng, 1, 64);
            (0..n).map(|_| rng.next_u64() & 1 == 1).collect::<Vec<bool>>()
        },
        |keep| {
            let (order, n_kept) = prune_order(keep);
            let n = keep.len();
            if order.len() != n {
                return Err("not a permutation (length)".into());
            }
            let mut seen = vec![false; n];
            for &i in &order {
                if seen[i] {
                    return Err("not a permutation (dup)".into());
                }
                seen[i] = true;
            }
            let expect_kept = keep.iter().filter(|&&k| k).count().max(1);
            if n_kept != expect_kept {
                return Err(format!("n_kept {n_kept} != {expect_kept}"));
            }
            // kept prefix preserves original order
            let kept_slice = &order[..n_kept];
            for w in kept_slice.windows(2) {
                if w[0] >= w[1] {
                    return Err("kept order not stable".into());
                }
            }
            // all kept indices (when any) are keep=true
            if keep.iter().any(|&k| k) {
                for &i in kept_slice {
                    if !keep[i] {
                        return Err(format!("pruned token {i} in kept prefix"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn threshold_schedule_invariants() {
    propcheck(
        "schedule",
        100,
        |rng| (gen_range(rng, 1, 48), gen_range(rng, 1, 48), gen_range(rng, 1, 512)),
        |&(l_from, l_to, n_cur)| {
            let s = ThresholdSchedule::default_for(l_from).fit_layers(l_to);
            if s.theta.len() != l_to || s.beta.len() != l_to {
                return Err("fit_layers length".into());
            }
            for li in 0..l_to {
                if s.beta[li] <= s.theta[li] {
                    return Err(format!("beta <= theta at layer {li}"));
                }
                let abs = s.theta_abs(li, n_cur);
                if !(abs.is_finite() && abs * n_cur as f64 - s.theta[li] < 1e-9) {
                    return Err("relative/absolute mismatch".into());
                }
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- fixed point

#[test]
fn fixed_point_roundtrip_error_bounded() {
    propcheck(
        "fix-roundtrip",
        300,
        |rng| (rng.next_f64() - 0.5) * 2e5,
        |&x| {
            let fx = Fix::default();
            let err = (fx.dec(fx.enc(x)) - x).abs();
            let ulp = 1.0 / fx.scale();
            if err <= ulp {
                Ok(())
            } else {
                Err(format!("err {err} > ulp {ulp}"))
            }
        },
    );
}

#[test]
fn ring_matmul_matches_f64() {
    propcheck(
        "ring-matmul",
        40,
        |rng| {
            let (n, k, m) = (gen_range(rng, 1, 8), gen_range(rng, 1, 8), gen_range(rng, 1, 8));
            let a = F64Mat::from_vec(
                n,
                k,
                (0..n * k).map(|_| (rng.next_f64() - 0.5) * 4.0).collect(),
            );
            let b = F64Mat::from_vec(
                k,
                m,
                (0..k * m).map(|_| (rng.next_f64() - 0.5) * 4.0).collect(),
            );
            (a, b)
        },
        |(a, b)| {
            let fx = Fix::default();
            let got = a.to_ring(fx).matmul(&b.to_ring(fx));
            let want = a.matmul(b);
            // ring product carries scale 2^2f
            let fx2 = Fix { frac_bits: fx.frac_bits * 2 };
            for i in 0..want.rows {
                for j in 0..want.cols {
                    let g = fx2.dec(got.at(i, j));
                    let w = want.at(i, j);
                    if (g - w).abs() > 1e-2 {
                        return Err(format!("({i},{j}): {g} vs {w}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn ring_mat_transpose_involution() {
    propcheck(
        "transpose",
        100,
        |rng| {
            let (r, c) = (gen_range(rng, 1, 12), gen_range(rng, 1, 12));
            RingMat::from_vec(r, c, (0..r * c).map(|_| rng.next_u64()).collect())
        },
        |m| {
            let t2 = m.transpose().transpose();
            if t2.data == m.data && t2.rows == m.rows {
                Ok(())
            } else {
                Err("transpose not involutive".into())
            }
        },
    );
}

// ---------------------------------------------------------------- workload

#[test]
fn workload_samples_always_wellformed() {
    propcheck(
        "workload",
        100,
        |rng| {
            let seq = gen_range(rng, 8, 128);
            let red = 0.1 + 0.8 * rng.next_f64();
            (seq, red, rng.next_u64())
        },
        |&(seq, red, seed)| {
            let cfg = ModelConfig::tiny();
            let wl = Workload { redundancy: red, ..Workload::qnli_like(&cfg, seq) };
            let mut rng = Xoshiro256::seed_from_u64(seed);
            let s = wl.sample(&mut rng);
            if s.ids.len() != seq {
                return Err("not padded to seq".into());
            }
            if s.label >= cfg.n_classes {
                return Err("label out of range".into());
            }
            if s.ids.iter().any(|&i| i >= cfg.vocab) {
                return Err("token out of vocab".into());
            }
            if s.ids[..s.real_len].iter().any(|&i| i == 0)
                || s.ids[s.real_len..].iter().any(|&i| i != 0)
            {
                return Err("padding structure broken".into());
            }
            Ok(())
        },
    );
}

// ---------------------------------------------------------------- router

/// End-to-end router property (real engines at test scale): every submitted
/// request is answered exactly once with the right logit arity.
#[test]
fn router_answers_every_request_exactly_once() {
    let cfg = ModelConfig::tiny();
    let weights = Arc::new(ModelWeights::salient(&cfg, 42));
    propcheck(
        "router-exactly-once",
        4,
        |rng| {
            let n = gen_range(rng, 1, 5);
            (0..n)
                .map(|i| {
                    InferenceRequest::new(
                        i as u64,
                        Workload::qnli_like(&ModelConfig::tiny(), gen_range(rng, 6, 12))
                            .batch(1, rng.next_u64())[0]
                            .ids
                            .clone(),
                        if rng.next_u64() & 1 == 0 {
                            EngineKind::CipherPrune
                        } else {
                            EngineKind::BoltNoWe
                        },
                    )
                })
                .collect::<Vec<_>>()
        },
        |reqs| {
            let mut router = Router::new(
                weights.clone(),
                RouterConfig {
                    policy: BatchPolicy {
                        max_batch: 2,
                        linger: Duration::from_millis(0),
                        min_bucket: 8,
                        max_tokens: 64,
                    },
                    workers: 2,
                    he_n: 128,
                    schedule: None,
                    threads: None,
                    transport: TransportSpec::Mem,
                    ..Default::default()
                },
            );
            let n = reqs.len();
            let resp = router.process(reqs.clone());
            if resp.len() != n {
                return Err(format!("{} responses for {n} requests", resp.len()));
            }
            let mut ids: Vec<u64> = resp.iter().map(|r| r.id).collect();
            ids.sort_unstable();
            ids.dedup();
            if ids.len() != n {
                return Err("duplicate/missing response ids".into());
            }
            for r in &resp {
                match &r.result {
                    Ok(res) if res.logits.len() == 2 => {}
                    Ok(_) => return Err("wrong logit arity".into()),
                    Err(e) => return Err(format!("request {} failed: {e}", r.id)),
                }
            }
            Ok(())
        },
    );
}
