//! Request-lifecycle hardening, pinned deterministically:
//!
//! - **Stall watchdog** — a peer that hangs *without* disconnecting (the
//!   failure mode nothing below a recv bound would ever surface) trips the
//!   session watchdog: the run fails typed, the session poisons, and drop
//!   still joins the party threads.
//! - **Mid-wave cut + replay** — a link severed mid-batch poisons the
//!   session, and a *fresh* session (different seed) replaying the same
//!   (nonce, content) wave produces bit-identical logits — the determinism
//!   the dispatcher's one-shot retry stands on.
//! - **Deadlines** — a request whose `deadline_ms` runs out while queued is
//!   answered `Expired` at dispatch without burning a session run, and its
//!   id is free for a fresh attempt.
//! - **Client backoff** — `call_with_retry` keeps retrying `Overloaded`
//!   sheds until its budget runs out, then still returns a typed response.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use cipherprune::coordinator::{
    BatchPolicy, BlockRun, EngineConfig, EngineKind, PreparedModel, Session,
};
use cipherprune::net::{
    new_transcript, Chan, FaultPlan, FaultTransport, MemTransport, NetError, Transport,
};
use cipherprune::nn::{ModelConfig, ModelWeights, Workload};
use cipherprune::serving::{ServeConfig, Server, ServingClient, WireRequest, WireResponse};

fn tiny() -> (Arc<ModelWeights>, Vec<usize>) {
    let cfg = ModelConfig::tiny();
    let w = Arc::new(ModelWeights::salient(&cfg, 42));
    let ids = Workload::qnli_like(&cfg, 8).batch(1, 17)[0].ids.clone();
    (w, ids)
}

/// A transport whose delivery the test can hold: sends still land in the
/// inner queue, receives see nothing — the peer looks hung but connected.
struct HoldSwitch {
    inner: Box<dyn Transport>,
    hold: Arc<AtomicBool>,
}

impl Transport for HoldSwitch {
    fn send_frame(&mut self, frame: Vec<u8>) -> Result<(), NetError> {
        self.inner.send_frame(frame)
    }

    fn recv_frame(&mut self) -> Result<Vec<u8>, NetError> {
        while self.hold.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(5));
        }
        self.inner.recv_frame()
    }

    fn recv_frame_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>, NetError> {
        if self.hold.load(Ordering::SeqCst) {
            std::thread::sleep(timeout);
            return Ok(None);
        }
        self.inner.recv_frame_timeout(timeout)
    }

    fn name(&self) -> &'static str {
        "hold"
    }
}

/// A peer that stalls (hangs without disconnecting) trips the watchdog: the
/// request fails with the typed stall error instead of hanging forever, the
/// session poisons, and `drop` still joins the party threads — which is the
/// test finishing at all.
#[test]
fn stalled_peer_trips_watchdog_and_poisons_session() {
    let (w, ids) = tiny();
    let model = Arc::new(PreparedModel::prepare(w));
    let (ta, tb) = MemTransport::pair();
    let hold = Arc::new(AtomicBool::new(false));
    let ha = HoldSwitch { inner: Box::new(ta), hold: hold.clone() };
    let hb = HoldSwitch { inner: Box::new(tb), hold: hold.clone() };
    let t = new_transcript();
    let ca = Chan::over(Box::new(ha), 0, t.clone());
    let cb = Chan::over(Box::new(hb), 1, t.clone());
    let ec = EngineConfig::for_tests(EngineKind::CipherPrune)
        .stall_timeout(Duration::from_millis(200));
    let mut s = Session::start_over(model, ec, (ca, cb, t)).expect("session start");

    let ok = s.infer(&ids).expect("healthy link serves the request");
    assert_eq!(ok.logits.len(), 2);
    assert!(s.poisoned().is_none());

    hold.store(true, Ordering::SeqCst);
    let t0 = Instant::now();
    let err = s.infer(&ids).expect_err("a stalled peer must trip the watchdog");
    let msg = format!("{err:#}");
    assert!(
        msg.contains("stalled") || msg.contains("watchdog"),
        "typed stall error surfaced: {msg}"
    );
    assert!(s.poisoned().is_some(), "the stall poisons the session");
    assert!(t0.elapsed() < Duration::from_secs(30), "watchdog fired, not a hang");

    let again = s.infer(&ids).expect_err("poisoned session fails fast");
    assert!(format!("{again:#}").contains("poisoned"));
    // drop joins both party threads; the recv bound guarantees they exit
    // even though the hold is still engaged
    drop(s);
}

/// Counts send attempts across both endpoints — the same frame clock
/// [`FaultTransport`] drives its triggers with, so a calibration run can
/// name a trigger that provably lands mid-wave.
struct CountingTransport {
    inner: Box<dyn Transport>,
    sends: Arc<AtomicU64>,
}

impl Transport for CountingTransport {
    fn send_frame(&mut self, frame: Vec<u8>) -> Result<(), NetError> {
        self.sends.fetch_add(1, Ordering::SeqCst);
        self.inner.send_frame(frame)
    }

    fn recv_frame(&mut self) -> Result<Vec<u8>, NetError> {
        self.inner.recv_frame()
    }

    fn recv_frame_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>, NetError> {
        self.inner.recv_frame_timeout(timeout)
    }

    fn name(&self) -> &'static str {
        "counting"
    }
}

fn start_counted(model: Arc<PreparedModel>, ec: EngineConfig) -> (Session, Arc<AtomicU64>) {
    let (ta, tb) = MemTransport::pair();
    let sends = Arc::new(AtomicU64::new(0));
    let ca_t = CountingTransport { inner: Box::new(ta), sends: sends.clone() };
    let cb_t = CountingTransport { inner: Box::new(tb), sends: sends.clone() };
    let t = new_transcript();
    let ca = Chan::over(Box::new(ca_t), 0, t.clone());
    let cb = Chan::over(Box::new(cb_t), 1, t.clone());
    let s = Session::start_over(model, ec, (ca, cb, t)).expect("counted session start");
    (s, sends)
}

/// A link severed provably *mid-wave* (trigger calibrated between the
/// setup and end-of-wave frame counts of an identical fault-free run)
/// poisons the session — and a fresh session under a *different* seed
/// replays the same (nonce, content) wave bit-identically. That replay
/// determinism is exactly what the dispatcher's one-shot retry relies on:
/// alignment streams are keyed by (nonce, content), not by session seed.
#[test]
fn cut_mid_wave_poisons_and_fresh_session_replay_is_bit_identical() {
    let (w, ids) = tiny();
    let model = Arc::new(PreparedModel::prepare(w));
    let kind = EngineKind::CipherPrune;
    let wave = vec![BlockRun { nonce: 404, ids: ids.clone() }];
    let ec = || EngineConfig::for_tests(kind).seed(0xD0D0);

    // calibration: the protocol is deterministic, so a second session with
    // the same config crosses the same frame counts at the same points
    let (mut cal, sends) = start_counted(model.clone(), ec());
    let setup_frames = sends.load(Ordering::SeqCst);
    let reference = cal.infer_batch(&wave).expect("fault-free reference").pop().unwrap();
    let total_frames = sends.load(Ordering::SeqCst);
    assert!(total_frames > setup_frames, "a wave must cross frames to cut mid-wave");
    drop(cal);

    // same config under a plan that severs the link halfway into the wave
    let trigger = setup_frames + (total_frames - setup_frames) / 2;
    let (fa, fb) = FaultTransport::mem_pair(FaultPlan::cut(trigger));
    let t = new_transcript();
    let ca = Chan::over(Box::new(fa), 0, t.clone());
    let cb = Chan::over(Box::new(fb), 1, t.clone());
    let mut s = Session::start_over(model.clone(), ec(), (ca, cb, t))
        .expect("setup completes before the calibrated trigger");
    let err = s.infer_batch(&wave).expect_err("the cut lands mid-wave");
    assert!(format!("{err:#}").contains("disconnected"), "typed cut error: {err:#}");
    assert!(s.poisoned().is_some(), "a mid-wave cut poisons the session");
    drop(s);

    // the retry path: a fresh session on a DIFFERENT seed replays the wave
    let mut fresh = Session::start(model, ec().seed(0xF4E54)).expect("replacement session");
    let replayed = fresh.infer_batch(&wave).expect("replay succeeds").pop().unwrap();
    assert_eq!(
        replayed.logits,
        reference.logits,
        "replay on a fresh session is bit-identical to the fault-free transcript"
    );
}

fn serve_tiny(cfg: ServeConfig) -> (Server, String) {
    let w = Arc::new(ModelWeights::salient(&ModelConfig::tiny(), 42));
    let model = Arc::new(PreparedModel::prepare(w));
    let server = Server::start(model, cfg, "127.0.0.1:0", "127.0.0.1:0").expect("server start");
    let addr = server.addr().to_string();
    (server, addr)
}

fn fetch_metrics(addr: std::net::SocketAddr) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).expect("connect metrics");
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").expect("send GET");
    let mut body = String::new();
    s.read_to_string(&mut body).expect("read metrics");
    body
}

/// A request whose relative deadline runs out while it lingers in the
/// batcher is answered with the typed `Expired` — no session run is spent
/// on it — and its id is immediately free for a fresh attempt.
#[test]
fn expired_deadline_answers_typed_and_frees_the_id() {
    let policy = BatchPolicy {
        max_batch: 8,
        linger: Duration::from_millis(150),
        min_bucket: 8,
        max_tokens: 32,
    };
    let (mut server, addr) =
        serve_tiny(ServeConfig { shards: 1, policy, ..ServeConfig::for_tests() });
    let ids = tiny().1;

    let mut c = ServingClient::connect_retry(&addr, Duration::from_secs(5)).expect("connect");
    // 1 ms deadline vs 150 ms linger: expired long before dispatch
    let req = WireRequest {
        id: 7,
        engine: EngineKind::CipherPrune,
        nonce: 61,
        deadline_ms: 1,
        ids: ids.clone(),
    };
    match c.call(&req).expect("call") {
        WireResponse::Expired { id, detail } => {
            assert_eq!(id, 7);
            assert!(detail.contains("deadline"), "{detail}");
        }
        other => panic!("expected Expired, got {other:?}"),
    }
    assert_eq!(server.stats().expired.load(Ordering::SeqCst), 1);
    assert_eq!(server.stats().failed.load(Ordering::SeqCst), 0, "expiry is not a failure");

    // the id settled — a fresh attempt with budget reuses it and completes
    let retry = WireRequest { deadline_ms: 0, ..req };
    match c.call(&retry).expect("call") {
        WireResponse::Result { id, logits, .. } => {
            assert_eq!(id, 7);
            assert!(!logits.is_empty());
        }
        other => panic!("expected Result on the fresh attempt, got {other:?}"),
    }

    let body = fetch_metrics(server.metrics_addr());
    assert!(body.contains("cipherprune_requests_expired_total 1"), "expired counter exported");
    server.shutdown();
    assert_eq!(server.stats().completed.load(Ordering::SeqCst), 1);
}

/// `call_with_retry` rides out `Overloaded` sheds with backoff and still
/// returns a typed response when the budget runs out; against a healthy
/// server it returns the first `Result` without spending the budget.
#[test]
fn call_with_retry_backs_off_overloaded_until_budget() {
    // max_queue 0: every admission sheds, so the retry loop runs dry
    let (mut server, addr) = serve_tiny(ServeConfig {
        shards: 1,
        policy: BatchPolicy {
            max_batch: 4,
            linger: Duration::from_millis(10),
            min_bucket: 8,
            max_tokens: 32,
        },
        max_queue: 0,
        ..ServeConfig::for_tests()
    });
    let ids = tiny().1;
    let req = WireRequest {
        id: 1,
        engine: EngineKind::CipherPrune,
        nonce: 31,
        deadline_ms: 0,
        ids: ids.clone(),
    };
    let mut c = ServingClient::connect_retry(&addr, Duration::from_secs(5)).expect("connect");
    let resp = c
        .call_with_retry(&req, Duration::from_millis(5), Duration::from_millis(120))
        .expect("typed response even at budget exhaustion");
    assert!(matches!(resp, WireResponse::Overloaded { .. }), "got {resp:?}");
    let sheds = server.stats().shed_overloaded.load(Ordering::SeqCst);
    assert!(sheds >= 2, "the budget bought retries, not a single attempt (sheds: {sheds})");
    server.shutdown();

    // healthy server: first attempt answers, no shed counted
    let (mut server, addr) = serve_tiny(ServeConfig {
        shards: 1,
        policy: BatchPolicy {
            max_batch: 4,
            linger: Duration::from_millis(10),
            min_bucket: 8,
            max_tokens: 32,
        },
        ..ServeConfig::for_tests()
    });
    let mut c = ServingClient::connect_retry(&addr, Duration::from_secs(5)).expect("connect");
    let resp = c
        .call_with_retry(&req, Duration::from_millis(5), Duration::from_secs(5))
        .expect("call");
    assert!(matches!(resp, WireResponse::Result { .. }), "got {resp:?}");
    assert_eq!(server.stats().shed_overloaded.load(Ordering::SeqCst), 0);
    server.shutdown();
}
