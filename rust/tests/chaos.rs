//! Seeded chaos campaign through the full serving stack: every shard
//! session is built over a [`ChaosSpec`] transport, so each P0⇄P1 link
//! draws a deterministic-per-seed fault plan (cut / stall / flip / benign).
//! The campaign pins the lifecycle contract under faults:
//!
//! 1. **Exactly one typed answer per request** — `Result`, `Failed`,
//!    `Expired`, or a shed; never silence, never a duplicate.
//! 2. **No hangs, no leaked threads** — the stall watchdog unwedges hung
//!    party links, so `Server::shutdown` (which joins every connection,
//!    shard, and party thread) returns; the test completing IS the check.
//! 3. **Answered results are bit-identical to a fault-free run** — logits
//!    are deterministic in (nonce, content) whatever faults or session
//!    rebuilds happened along the way, pinned against direct fault-free
//!    sessions.
//!
//! Plus a calibrated single-fault scenario: a link provably cut *mid-wave*
//! is healed by the dispatcher's one-shot replay on a fresh session — the
//! client sees a normal `Result`, bit-identical, and only the retry
//! counters betray that anything happened.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use cipherprune::coordinator::{
    BatchPolicy, BlockRun, EngineConfig, EngineKind, PreparedModel, Session,
};
use cipherprune::net::{
    new_transcript, Chan, ChaosSpec, FaultPlan, MemTransport, NetError, Transport, TransportSpec,
};
use cipherprune::nn::{real_len, ModelConfig, ModelWeights, Workload};
use cipherprune::serving::{
    shard_seed, ServeConfig, Server, ServingClient, WireRequest, WireResponse,
};

fn tiny_model() -> Arc<PreparedModel> {
    let w = Arc::new(ModelWeights::salient(&ModelConfig::tiny(), 42));
    Arc::new(PreparedModel::prepare(w))
}

fn sample_ids(seed: u64) -> Vec<usize> {
    let cfg = ModelConfig::tiny();
    let ids = Workload::qnli_like(&cfg, 8).batch(1, seed)[0].ids.clone();
    let real = real_len(&ids);
    ids[..real].to_vec()
}

fn chaos_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, linger: Duration::from_millis(10), min_bucket: 8, max_tokens: 32 }
}

fn fetch_metrics(addr: std::net::SocketAddr) -> String {
    use std::io::{Read, Write};
    let mut s = std::net::TcpStream::connect(addr).expect("connect metrics");
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").expect("send GET");
    let mut body = String::new();
    s.read_to_string(&mut body).expect("read metrics");
    body
}

/// Counts send attempts across both endpoints — the same frame clock a
/// [`FaultTransport`](cipherprune::net::FaultTransport) drives its triggers
/// with, so a calibration run can name a trigger that lands mid-wave.
struct CountingTransport {
    inner: Box<dyn Transport>,
    sends: Arc<AtomicU64>,
}

impl Transport for CountingTransport {
    fn send_frame(&mut self, frame: Vec<u8>) -> Result<(), NetError> {
        self.sends.fetch_add(1, Ordering::SeqCst);
        self.inner.send_frame(frame)
    }

    fn recv_frame(&mut self) -> Result<Vec<u8>, NetError> {
        self.inner.recv_frame()
    }

    fn recv_frame_timeout(&mut self, timeout: Duration) -> Result<Option<Vec<u8>>, NetError> {
        self.inner.recv_frame_timeout(timeout)
    }

    fn name(&self) -> &'static str {
        "counting"
    }
}

/// A single fault, provably mid-wave, healed invisibly: calibrate the frame
/// counts a fault-free run crosses (setup, end of one wave), scan seeds for
/// a [`ChaosSpec`] whose first drawn plan cuts inside that window and whose
/// second is benign, then serve one request through it. The first session
/// dies mid-wave; the dispatcher evicts it and replays on a fresh session;
/// the client sees a plain `Result`, bit-identical to the fault-free
/// transcript — only the retry counters show anything happened.
#[test]
fn calibrated_mid_wave_cut_is_healed_by_one_shot_replay() {
    let model = tiny_model();
    let kind = EngineKind::CipherPrune;
    let ids = sample_ids(17);
    let wave = vec![BlockRun { nonce: 42, ids: ids.clone() }];

    // calibration run: EXACTLY the engine config shard 0 will use for its
    // first session of this kind (seed included), over a counting transport
    let ec = EngineConfig::new(kind).he_n(128).seed(shard_seed(0, kind, 0));
    let (ta, tb) = MemTransport::pair();
    let sends = Arc::new(AtomicU64::new(0));
    let ca_t = CountingTransport { inner: Box::new(ta), sends: sends.clone() };
    let cb_t = CountingTransport { inner: Box::new(tb), sends: sends.clone() };
    let t = new_transcript();
    let ca = Chan::over(Box::new(ca_t), 0, t.clone());
    let cb = Chan::over(Box::new(cb_t), 1, t.clone());
    let mut cal = Session::start_over(model.clone(), ec, (ca, cb, t)).expect("calibration");
    let setup_frames = sends.load(Ordering::SeqCst);
    let reference = cal.infer_batch(&wave).expect("fault-free reference").pop().unwrap();
    let total_frames = sends.load(Ordering::SeqCst);
    assert!(total_frames > setup_frames, "a wave must cross frames");
    drop(cal);

    // scan for a seed whose campaign is [cut mid-wave, benign]: plan 0 cuts
    // inside the wave's frame window, plan 1 (the replacement session's
    // link) is clean
    let mut spec = None;
    for seed in 0..500_000u64 {
        let s = ChaosSpec::new(seed);
        let p0 = s.plan(0);
        let mid_wave_cut =
            p0.cut_after_frames.is_some_and(|a| a >= setup_frames && a < total_frames);
        if mid_wave_cut && s.plan(1) == FaultPlan::benign() {
            spec = Some(s);
            break;
        }
    }
    let spec = spec.expect("a seed with a [mid-wave cut, benign] campaign exists in range");

    let cfg = ServeConfig {
        shards: 1,
        policy: chaos_policy(),
        transport: TransportSpec::Chaos(spec),
        ..ServeConfig::for_tests()
    };
    let mut server =
        Server::start(model, cfg, "127.0.0.1:0", "127.0.0.1:0").expect("server start");
    let addr = server.addr().to_string();

    let mut c = ServingClient::connect_retry(&addr, Duration::from_secs(5)).expect("connect");
    let req = WireRequest { id: 1, engine: kind, nonce: 42, deadline_ms: 0, ids: ids.clone() };
    match c.call(&req).expect("call") {
        WireResponse::Result { id, logits, .. } => {
            assert_eq!(id, 1);
            assert_eq!(
                logits,
                reference.logits,
                "the healed response is bit-identical to the fault-free transcript"
            );
        }
        other => panic!("the retry must heal the cut invisibly, got {other:?}"),
    }

    let body = fetch_metrics(server.metrics_addr());
    assert!(body.contains("cipherprune_retries_total 1\n"), "one wave retried");
    assert!(body.contains("cipherprune_retry_successes_total 1\n"), "and it recovered");
    server.shutdown();
    let stats = server.stats();
    assert_eq!(stats.completed.load(Ordering::SeqCst), 1);
    assert_eq!(stats.failed.load(Ordering::SeqCst), 0, "the client never saw the fault");
}

/// One campaign at one seed: 12 clients across 4 (kind, nonce, content)
/// classes plus one deadline-carrying request, against 2 shards whose
/// session links all draw seeded fault plans. Every request must come back
/// with exactly one typed answer, every `Result` must be bit-identical to
/// the fault-free reference, the books must balance, and shutdown must
/// return (hung threads would wedge its joins — the watchdog is what
/// guarantees they cannot).
fn run_campaign(seed: u64) {
    let model = tiny_model();
    let base = sample_ids(17);
    let long: Vec<usize> = base.iter().chain(&base).copied().take(12).collect();
    let classes: Vec<(EngineKind, u64, Vec<usize>)> = vec![
        (EngineKind::CipherPrune, 900, base.clone()),
        (EngineKind::CipherPrune, 901, long.clone()),
        (EngineKind::BoltNoWe, 902, base.clone()),
        (EngineKind::BoltNoWe, 903, long.clone()),
    ];

    // fault-free references, one direct session per kind: logits depend
    // only on (nonce, content), so ANY healthy session of the kind agrees
    // with whatever session (original or post-fault replacement) served it
    let mut expect: std::collections::HashMap<u64, Vec<f64>> = std::collections::HashMap::new();
    for kind in [EngineKind::CipherPrune, EngineKind::BoltNoWe] {
        let mut sess = Session::start(model.clone(), EngineConfig::for_tests(kind))
            .expect("reference session");
        for (k, nonce, ids) in &classes {
            if *k != kind {
                continue;
            }
            let r = sess
                .infer_batch(&[BlockRun { nonce: *nonce, ids: ids.clone() }])
                .expect("reference infer")
                .pop()
                .unwrap();
            expect.insert(*nonce, r.logits);
        }
    }

    let cfg = ServeConfig {
        shards: 2,
        policy: chaos_policy(),
        transport: TransportSpec::Chaos(ChaosSpec::new(seed)),
        stall_timeout: Some(Duration::from_millis(300)),
        ..ServeConfig::for_tests()
    };
    let mut server =
        Server::start(model, cfg, "127.0.0.1:0", "127.0.0.1:0").expect("server start");
    let addr = server.addr().to_string();

    let n_clients = 12;
    let mut handles = Vec::new();
    for i in 0..n_clients {
        let addr = addr.clone();
        let (kind, nonce, ids) = classes[i % classes.len()].clone();
        handles.push(std::thread::spawn(move || {
            let mut c = ServingClient::connect_retry(&addr, Duration::from_secs(5))
                .expect("client connect");
            let req = WireRequest { id: 1 + i as u64, engine: kind, nonce, deadline_ms: 0, ids };
            // call() returns exactly one response for this id — a duplicate
            // or dropped answer would break recv_for's accounting
            (req, c.call(&req).expect("one typed answer per request"))
        }));
    }
    // one deadline-carrying request: with a 1 ms budget against a 10 ms
    // linger it all but certainly expires — either way the answer is typed
    let deadline_handle = {
        let addr = addr.clone();
        let (kind, nonce, ids) = classes[0].clone();
        std::thread::spawn(move || {
            let mut c = ServingClient::connect_retry(&addr, Duration::from_secs(5))
                .expect("client connect");
            let req = WireRequest { id: 99, engine: kind, nonce, deadline_ms: 1, ids };
            (req, c.call(&req).expect("one typed answer per request"))
        })
    };

    let (mut results, mut faults) = (0u64, 0u64);
    for h in handles {
        let (req, resp) = h.join().expect("client thread");
        match resp {
            WireResponse::Result { id, logits, .. } => {
                assert_eq!(id, req.id);
                assert_eq!(
                    logits,
                    expect[&req.nonce],
                    "an answered Result is bit-identical to the fault-free run \
                     (seed {seed:#x}, nonce {})",
                    req.nonce
                );
                results += 1;
            }
            WireResponse::Failed { id, detail } => {
                assert_eq!(id, req.id);
                assert!(!detail.is_empty(), "failures carry a reason");
                faults += 1;
            }
            other => panic!("unexpected response under chaos (seed {seed:#x}): {other:?}"),
        }
    }
    match deadline_handle.join().expect("deadline client") {
        (req, WireResponse::Expired { id, .. }) => assert_eq!(id, req.id),
        (req, WireResponse::Result { id, logits, .. }) => {
            // dispatched inside 1 ms: legitimate, must still be correct
            assert_eq!(id, req.id);
            assert_eq!(logits, expect[&req.nonce]);
        }
        (_, WireResponse::Failed { detail, .. }) => {
            assert!(!detail.is_empty(), "failures carry a reason");
        }
        (_, other) => panic!("deadline request got an untyped outcome: {other:?}"),
    }
    assert_eq!(results + faults, n_clients as u64, "exactly one outcome per request");

    // the books balance: everything admitted was settled one way
    let body = fetch_metrics(server.metrics_addr());
    assert!(body.contains("cipherprune_queue_depth 0"), "no request left in flight");
    // shutdown joins every connection, shard, and (via Session drop) party
    // thread — a leaked or hung thread would wedge it here
    server.shutdown();
    let stats = server.stats();
    let settled = stats.completed.load(Ordering::SeqCst)
        + stats.failed.load(Ordering::SeqCst)
        + stats.expired.load(Ordering::SeqCst)
        + stats.cancelled.load(Ordering::SeqCst);
    assert_eq!(
        settled,
        stats.accepted.load(Ordering::SeqCst),
        "every admitted request settled exactly once (seed {seed:#x})"
    );
}

/// The pinned-seed campaign: three seeds with distinct fault schedules.
/// Seeds are fixed so CI failures reproduce locally byte for byte.
#[test]
fn chaos_campaign_every_request_gets_exactly_one_typed_answer() {
    for seed in [0xC4A05u64, 0x00BEEF, 0x7E57AB] {
        run_campaign(seed);
    }
}
