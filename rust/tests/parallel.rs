//! Thread-count invariance: the worker-pool parallelism added to the HE/OT
//! hot paths must never change what the protocols compute *or* what crosses
//! the wire. Every test runs the same computation at pool sizes 1, 2, and
//! host-max and asserts bit-identical outputs, transcript byte/message
//! counts, AND per-endpoint wire-content digests (`Transcript::content`), so
//! a content-level determinism regression — e.g. drawing encryption seeds
//! inside a parallel closure — cannot slip past on matching sizes alone.
//! (CI additionally re-runs the whole suite with `THREADS=1`.)

use std::sync::Arc;

use cipherprune::coordinator::{BlockRun, EngineConfig, EngineKind, PreparedModel, Session};
use cipherprune::fixed::{F64Mat, Fix, RingMat};
use cipherprune::gates::TripleMode;
use cipherprune::nn::{ModelConfig, ModelWeights, Workload};
use cipherprune::party::{run2_owned_sym, transcript_total};
use cipherprune::protocols::matmul::{pi_matmul_shared, pi_matmul_weights};
use cipherprune::protocols::Engine2P;
use cipherprune::util::{WorkerPool, Xoshiro256};

fn pool_sizes() -> Vec<usize> {
    let max = WorkerPool::auto().threads().max(2);
    let mut v = vec![1, 2, max];
    v.dedup();
    v
}

fn rand_f64_mat(rows: usize, cols: usize, amp: f64, seed: u64) -> F64Mat {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    F64Mat::from_vec(
        rows,
        cols,
        (0..rows * cols).map(|_| (rng.next_f64() * 2.0 - 1.0) * amp).collect(),
    )
}

fn share_mat(m: &F64Mat, fix: Fix, seed: u64) -> (RingMat, RingMat) {
    let ring = m.to_ring(fix);
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let r: Vec<u64> = (0..ring.data.len()).map(|_| rng.next_u64()).collect();
    let s0 = RingMat::from_vec(
        ring.rows,
        ring.cols,
        ring.data.iter().zip(&r).map(|(x, y)| x.wrapping_sub(*y)).collect(),
    );
    let s1 = RingMat::from_vec(ring.rows, ring.cols, r);
    (s0, s1)
}

/// Both Π_MatMul variants end-to-end at each pool size: identical output
/// shares on both parties, identical transcript bytes and message counts.
#[test]
fn matmul_protocols_invariant_across_pool_sizes() {
    let fx = Fix::default();
    let x = rand_f64_mat(5, 12, 4.0, 1);
    let w = rand_f64_mat(12, 9, 1.5, 2);
    let y = rand_f64_mat(9, 7, 2.0, 3);
    let (x0, x1) = share_mat(&x, fx, 4);
    let (y0, y1) = share_mat(&y, fx, 5);
    let wr = w.to_ring(fx);
    let m = w.cols;

    let mut baseline: Option<(Vec<u64>, Vec<u64>, u64, u64, [u64; 2])> = None;
    for &threads in &pool_sizes() {
        let (x0, x1, y0, y1, wr) =
            (x0.clone(), x1.clone(), y0.clone(), y1.clone(), wr.clone());
        let (r0, r1, t) = run2_owned_sym(71, move |ctx| {
            let mut e = Engine2P::with_pool(
                ctx,
                TripleMode::Ot,
                128,
                fx,
                WorkerPool::new(threads),
            );
            let (xs, ys, wref) = if e.is_p0() {
                (x0.clone(), y0.clone(), Some(&wr))
            } else {
                (x1.clone(), y1.clone(), None)
            };
            let a = pi_matmul_weights(&mut e, &xs, wref, m);
            let b = pi_matmul_shared(&mut e, &a, &ys);
            let mut out = a.data;
            out.extend(b.data);
            out
        });
        let total = transcript_total(&t);
        let digest = t.lock().unwrap().content;
        let cur = (r0, r1, total.bytes, total.msgs, digest);
        match &baseline {
            None => baseline = Some(cur),
            Some(b) => {
                assert_eq!(b.0, cur.0, "P0 shares differ at {threads} threads");
                assert_eq!(b.1, cur.1, "P1 shares differ at {threads} threads");
                assert_eq!(b.2, cur.2, "transcript bytes differ at {threads} threads");
                assert_eq!(b.3, cur.3, "transcript msgs differ at {threads} threads");
                assert_eq!(b.4, cur.4, "wire content differs at {threads} threads");
            }
        }
    }
}

/// A full `Session::infer` (every protocol in the pipeline, OT extension
/// included) at each pool size: identical logits, identical setup traffic,
/// identical per-request transcript bytes.
#[test]
fn session_infer_invariant_across_pool_sizes() {
    let cfg = ModelConfig::tiny();
    let w = Arc::new(ModelWeights::salient(&cfg, 42));
    let ids = Workload::qnli_like(&cfg, 8).batch(1, 17)[0].ids.clone();

    let mut baseline: Option<(Vec<f64>, u64, u64, u64, [u64; 2])> = None;
    for &threads in &pool_sizes() {
        let ec = EngineConfig::for_tests(EngineKind::CipherPrune).threads(threads);
        let model = Arc::new(PreparedModel::prepare(w.clone()));
        let mut session = Session::start(model, ec).expect("session start");
        let r = session.infer(&ids).expect("infer");
        let req = r.total_stats();
        let cur = (
            r.logits.clone(),
            session.setup_stats().bytes,
            req.bytes,
            req.msgs,
            session.transcript_digest(),
        );
        match &baseline {
            None => baseline = Some(cur),
            Some(b) => {
                assert_eq!(b.0, cur.0, "logits differ at {threads} threads");
                assert_eq!(b.1, cur.1, "setup bytes differ at {threads} threads");
                assert_eq!(b.2, cur.2, "request bytes differ at {threads} threads");
                assert_eq!(b.3, cur.3, "request msgs differ at {threads} threads");
                assert_eq!(b.4, cur.4, "wire content differs at {threads} threads");
            }
        }
    }
}

/// A fused batch (three mixed-length requests in ONE pipeline run — block
/// masks, aligned truncation, per-block bookkeeping) at each pool size:
/// identical per-request logits, identical transcript bytes/messages, and
/// identical wire-content digests.
#[test]
fn fused_batch_invariant_across_pool_sizes() {
    let cfg = ModelConfig::tiny();
    let w = Arc::new(ModelWeights::salient(&cfg, 42));
    let items: Vec<BlockRun> = Workload::qnli_like(&cfg, 8)
        .batch(3, 31)
        .into_iter()
        .enumerate()
        .map(|(i, s)| BlockRun { nonce: 50 + i as u64, ids: s.ids })
        .collect();

    let mut baseline: Option<(Vec<Vec<f64>>, u64, u64, [u64; 2])> = None;
    for &threads in &pool_sizes() {
        let ec = EngineConfig::for_tests(EngineKind::CipherPrune).threads(threads);
        let model = Arc::new(PreparedModel::prepare(w.clone()));
        let mut session = Session::start(model, ec).expect("session start");
        let rs = session.infer_batch(&items).expect("fused infer");
        assert_eq!(rs.len(), items.len());
        let logits: Vec<Vec<f64>> = rs.iter().map(|r| r.logits.clone()).collect();
        let req = rs[0].total_stats(); // batch-level, shared by all members
        let cur = (logits, req.bytes, req.msgs, session.transcript_digest());
        match &baseline {
            None => baseline = Some(cur),
            Some(b) => {
                assert_eq!(b.0, cur.0, "fused logits differ at {threads} threads");
                assert_eq!(b.1, cur.1, "batch bytes differ at {threads} threads");
                assert_eq!(b.2, cur.2, "batch msgs differ at {threads} threads");
                assert_eq!(b.3, cur.3, "wire content differs at {threads} threads");
            }
        }
    }
}

/// The one-shot shim and a threaded fresh session still agree exactly (the
/// PR-1 contract survives the parallel engine).
#[test]
fn one_shot_matches_threaded_session() {
    let cfg = ModelConfig::tiny();
    let w = Arc::new(ModelWeights::salient(&cfg, 42));
    let ids = Workload::qnli_like(&cfg, 8).batch(1, 17)[0].ids.clone();
    let max = WorkerPool::auto().threads().max(2);
    let ec = EngineConfig::for_tests(EngineKind::CipherPrune).threads(max);
    let one_shot = cipherprune::coordinator::run_inference(&ec, &w, &ids);
    let model = Arc::new(PreparedModel::prepare(w));
    let mut session = Session::start(model, ec).expect("session start");
    assert_eq!(session.infer(&ids).expect("infer").logits, one_shot.logits);
}
