//! End-to-end tests of the serving front door (`cipherprune::serving`):
//! many concurrent clients over real loopback TCP against ≥ 2 session
//! shards, with the three contract pillars pinned:
//!
//! 1. **Bit-identity** — every accepted response's logits equal a direct
//!    `Session::infer` of the same (nonce, content) under the deterministic
//!    shard seed (`shard_for`/`shard_seed` name the session out-of-band).
//! 2. **Typed shedding** — admission control answers every refused request
//!    with a typed `Overloaded`/`Rejected`, the process stays alive, and a
//!    client never hangs on a shed request.
//! 3. **Isolation** — a connection severed mid-load cancels its own queued
//!    work and nothing else; other clients' requests complete normally.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::process::{Command, Stdio};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

use cipherprune::coordinator::{
    bucket_for, BatchPolicy, BlockRun, EngineConfig, EngineKind, PreparedModel, Session,
};
use cipherprune::net::Transport;
use cipherprune::nn::{real_len, ModelConfig, ModelWeights, Workload};
use cipherprune::serving::{
    decode_response, encode_request, shard_for, shard_seed, RejectCode, ServeConfig, Server,
    ServingClient, WireRequest, WireResponse,
};

fn tiny_model() -> Arc<PreparedModel> {
    let w = Arc::new(ModelWeights::salient(&ModelConfig::tiny(), 42));
    Arc::new(PreparedModel::prepare(w))
}

fn sample_ids(seed: u64) -> Vec<usize> {
    let cfg = ModelConfig::tiny();
    let ids = Workload::qnli_like(&cfg, 8).batch(1, seed)[0].ids.clone();
    let real = real_len(&ids);
    ids[..real].to_vec()
}

fn test_policy() -> BatchPolicy {
    BatchPolicy { max_batch: 4, linger: Duration::from_millis(10), min_bucket: 8, max_tokens: 32 }
}

fn fetch_metrics(addr: std::net::SocketAddr) -> String {
    let mut s = TcpStream::connect(addr).expect("connect metrics");
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: t\r\n\r\n").expect("send GET");
    let mut body = String::new();
    s.read_to_string(&mut body).expect("read metrics");
    body
}

/// 64 concurrent clients over loopback TCP, two shards, two engine kinds,
/// three length classes: every accepted response is bit-identical to a
/// direct `Session::infer` with the same (nonce, content) on a session
/// seeded by `shard_seed`. Several clients deliberately share one
/// (nonce, content) class, forcing the shards to split same-nonce waves.
/// Finishes with a parse of the Prometheus endpoint.
#[test]
fn loopback_fleet_is_bit_identical_to_direct_inference() {
    let model = tiny_model();
    let policy = test_policy();
    let n_shards = 2;
    let cfg = ServeConfig { shards: n_shards, policy, ..ServeConfig::for_tests() };
    let mut server = Server::start(model.clone(), cfg, "127.0.0.1:0", "127.0.0.1:0")
        .expect("server start");
    let addr = server.addr().to_string();

    // 8 request classes over 2 kinds and 3 lengths; 64 clients = 8 per class
    let base = sample_ids(17);
    let long: Vec<usize> = base.iter().chain(&base).chain(&base).copied().take(12).collect();
    let classes: Vec<(EngineKind, u64, Vec<usize>)> = (0..8u64)
        .map(|c| {
            let kind = if c % 2 == 0 { EngineKind::CipherPrune } else { EngineKind::BoltNoWe };
            let ids = match c % 3 {
                0 => base[..4.min(base.len())].to_vec(),
                1 => base.clone(),
                _ => long.clone(),
            };
            (kind, 500 + c, ids)
        })
        .collect();

    let n_clients = 64;
    let mut handles = Vec::new();
    for i in 0..n_clients {
        let addr = addr.clone();
        let (kind, nonce, ids) = classes[i % classes.len()].clone();
        handles.push(std::thread::spawn(move || {
            let mut c = ServingClient::connect_retry(&addr, Duration::from_secs(5))
                .expect("client connect");
            let req = WireRequest { id: 1 + i as u64, engine: kind, nonce, deadline_ms: 0, ids };
            let resp = c.call(&req).expect("serving call");
            (req, resp)
        }));
    }

    // direct reference runs: one session per (shard, kind), seeded exactly
    // as the shard seeds its first session for that kind
    let mut reference: HashMap<(usize, EngineKind), Session> = HashMap::new();
    let mut expect: HashMap<u64, Vec<f64>> = HashMap::new();
    for (kind, nonce, ids) in &classes {
        let shard = shard_for(*kind, bucket_for(ids.len(), &policy), n_shards);
        let sess = reference.entry((shard, *kind)).or_insert_with(|| {
            let ec = EngineConfig::for_tests(*kind).seed(shard_seed(shard, *kind, 0));
            Session::start(model.clone(), ec).expect("reference session")
        });
        let r = sess
            .infer_batch(&[BlockRun { nonce: *nonce, ids: ids.clone() }])
            .expect("reference infer")
            .pop()
            .unwrap();
        expect.insert(*nonce, r.logits);
    }

    let mut served = 0;
    for h in handles {
        let (req, resp) = h.join().expect("client thread");
        match resp {
            WireResponse::Result { id, logits, .. } => {
                assert_eq!(id, req.id);
                assert_eq!(
                    logits,
                    expect[&req.nonce],
                    "served logits must be bit-identical to direct inference \
                     (kind {:?}, nonce {})",
                    req.engine,
                    req.nonce
                );
                served += 1;
            }
            other => panic!("expected a Result, got {other:?}"),
        }
    }
    assert_eq!(served, n_clients);

    // Prometheus endpoint: parseable text exposition with the serving gauges
    let body = fetch_metrics(server.metrics_addr());
    assert!(body.starts_with("HTTP/1.1 200 OK"));
    let text = body.split("\r\n\r\n").nth(1).expect("body after headers");
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            continue;
        }
        let (_, value) = line.rsplit_once(' ').expect("metric line");
        assert!(value.parse::<f64>().is_ok(), "unparseable metric line {line:?}");
    }
    assert!(text.contains("cipherprune_queue_depth 0"), "all work settled");
    assert!(text.contains("cipherprune_shed_overloaded_total 0"));
    assert!(text.contains(&format!("cipherprune_requests_completed_total {n_clients}")));
    assert!(text.contains("cipherprune_engine_requests_total{engine=\"cipherprune\"} 32"));
    assert!(text.contains("cipherprune_engine_requests_total{engine=\"bolt-no-we\"} 32"));

    let stats = server.stats();
    assert_eq!(stats.completed.load(Ordering::SeqCst), n_clients as u64);
    assert_eq!(stats.failed.load(Ordering::SeqCst), 0);
    assert_eq!(stats.cancelled.load(Ordering::SeqCst), 0);
    server.shutdown();
}

/// A full queue sheds with the retryable `Overloaded` (and the server keeps
/// answering afterwards — shed ≠ dead); every malformed or limit-violating
/// request gets its typed `Rejected`; a request left queued at shutdown is
/// cancelled, not leaked.
#[test]
fn overload_and_rejects_are_typed_and_never_hang() {
    let model = tiny_model();

    // max_queue 0: every well-formed request sheds as Overloaded
    let cfg = ServeConfig {
        shards: 1,
        policy: test_policy(),
        max_queue: 0,
        ..ServeConfig::for_tests()
    };
    let mut server = Server::start(model.clone(), cfg, "127.0.0.1:0", "127.0.0.1:0")
        .expect("server start");
    let addr = server.addr().to_string();
    let mut c = ServingClient::connect_retry(&addr, Duration::from_secs(5)).expect("connect");
    for id in 1..=3u64 {
        let req = WireRequest {
            id,
            engine: EngineKind::CipherPrune,
            nonce: id,
            deadline_ms: 0,
            ids: sample_ids(17),
        };
        match c.call(&req).expect("call") {
            WireResponse::Overloaded { id: rid, .. } => assert_eq!(rid, id),
            other => panic!("expected Overloaded, got {other:?}"),
        }
    }
    assert_eq!(server.stats().shed_overloaded.load(Ordering::SeqCst), 3);
    let body = fetch_metrics(server.metrics_addr());
    assert!(body.contains("cipherprune_shed_overloaded_total 3"), "shed counter exported");
    server.shutdown();

    // per-request rejects: long linger + max_batch 8 parks the one admitted
    // request, so every subsequent violation is judged against live state
    let cfg = ServeConfig {
        shards: 1,
        policy: BatchPolicy {
            max_batch: 8,
            linger: Duration::from_secs(60),
            min_bucket: 8,
            max_tokens: 32,
        },
        max_queue: 64,
        max_inflight_per_conn: 1,
        ..ServeConfig::for_tests()
    };
    let mut server = Server::start(model, cfg, "127.0.0.1:0", "127.0.0.1:0").expect("server");
    let addr = server.addr().to_string();

    let ids = sample_ids(17);
    let mk = |id: u64, ids: Vec<usize>| WireRequest {
        id,
        engine: EngineKind::CipherPrune,
        nonce: id,
        deadline_ms: 0,
        ids,
    };
    let mut c = ServingClient::connect_retry(&addr, Duration::from_secs(5)).expect("connect");
    // id 1 admits and parks in the batcher (long linger, bucket not full)
    c.send(&mk(1, ids.clone())).expect("send");
    let expect_reject = |c: &mut ServingClient, req: &WireRequest, want: RejectCode| {
        match c.call(req).expect("call") {
            WireResponse::Rejected { id, code, detail } => {
                assert_eq!(id, req.id);
                assert_eq!(code, want, "unexpected reject cause: {detail}");
                assert!(!detail.is_empty());
            }
            other => panic!("expected Rejected({want:?}), got {other:?}"),
        }
    };
    expect_reject(&mut c, &mk(1, ids.clone()), RejectCode::DuplicateId);
    expect_reject(&mut c, &mk(2, ids.clone()), RejectCode::TooManyInFlight);
    expect_reject(&mut c, &mk(3, vec![]), RejectCode::EmptyInput);
    expect_reject(&mut c, &mk(4, vec![1; 100]), RejectCode::TooLong);

    // wire-level garbage over a raw transport: typed rejects, no hang
    let mut raw = cipherprune::net::TcpTransport::connect_retry(&addr, Duration::from_secs(5))
        .expect("raw connect");
    let mut bad_engine = encode_request(&mk(9, ids.clone()));
    bad_engine[9] = 0xEE; // engine ordinal byte
    raw.send_frame(bad_engine).expect("send");
    match decode_response(&raw.recv_frame().expect("recv")).expect("decode") {
        WireResponse::Rejected { id, code, .. } => {
            assert_eq!((id, code), (9, RejectCode::UnknownEngine));
        }
        other => panic!("expected Rejected(UnknownEngine), got {other:?}"),
    }
    raw.send_frame(vec![0x7F, 1, 2, 3]).expect("send");
    match decode_response(&raw.recv_frame().expect("recv")).expect("decode") {
        WireResponse::Rejected { code, .. } => assert_eq!(code, RejectCode::Malformed),
        other => panic!("expected Rejected(Malformed), got {other:?}"),
    }
    assert_eq!(server.stats().shed_rejected.load(Ordering::SeqCst), 6);

    // the parked request is still queued; teardown must cancel it cleanly
    drop(c);
    drop(raw);
    server.shutdown();
    let stats = server.stats();
    assert_eq!(stats.accepted.load(Ordering::SeqCst), 1);
    assert_eq!(stats.cancelled.load(Ordering::SeqCst), 1, "queued work cancelled at teardown");
    assert_eq!(stats.queue_depth.load(Ordering::SeqCst), 0);
}

/// Regression for the typed decode paths: frames truncated mid-field, a
/// token count that lies about the payload, and trailing garbage must all
/// come back as `Rejected(Malformed)` — carrying the request id whenever
/// the header survived far enough to decode one — and the same connection
/// must keep answering afterwards. Before the decode paths were typed, any
/// of these killed the reader thread with an unwrap panic.
#[test]
fn truncated_frames_reject_typed_and_connection_survives() {
    let model = tiny_model();
    let cfg = ServeConfig { shards: 1, policy: test_policy(), ..ServeConfig::for_tests() };
    let mut server = Server::start(model, cfg, "127.0.0.1:0", "127.0.0.1:0").expect("server");
    let addr = server.addr().to_string();

    let mut raw = cipherprune::net::TcpTransport::connect_retry(&addr, Duration::from_secs(5))
        .expect("raw connect");
    let good = encode_request(&WireRequest {
        id: 42,
        engine: EngineKind::CipherPrune,
        nonce: 7,
        deadline_ms: 0,
        ids: sample_ids(23),
    });
    // layout: tag(1) ‖ id(8) ‖ engine(1) ‖ nonce(8) ‖ deadline(8) ‖ n(4) ‖ ids
    let n_off = 1 + 8 + 1 + 8 + 8;

    let mut expect_malformed = |frame: Vec<u8>, want_id: u64, what: &str| {
        raw.send_frame(frame).expect("send");
        match decode_response(&raw.recv_frame().expect("recv")).expect("decode") {
            WireResponse::Rejected { id, code, detail } => {
                assert_eq!(code, RejectCode::Malformed, "{what}: {detail}");
                assert_eq!(id, want_id, "{what}: reject should echo the decoded id");
                assert!(!detail.is_empty(), "{what}: detail must name the decode failure");
            }
            other => panic!("{what}: expected Rejected(Malformed), got {other:?}"),
        }
    };

    // header cut mid-id: no id decodes, so the reject answers with id 0
    expect_malformed(good[..5].to_vec(), 0, "mid-id truncation");
    // body cut mid-token-list: the id survived, so the reject carries it
    expect_malformed(good[..good.len() - 2].to_vec(), 42, "mid-ids truncation");
    // header cut mid-deadline: id survived, later field missing
    expect_malformed(good[..n_off - 3].to_vec(), 42, "mid-deadline truncation");
    // count field claims far more tokens than the frame holds
    let mut lying = good.clone();
    lying[n_off..n_off + 4].copy_from_slice(&u32::MAX.to_le_bytes());
    expect_malformed(lying, 42, "lying token count");
    // trailing bytes after a complete request
    let mut trailing = good.clone();
    trailing.push(0xAB);
    expect_malformed(trailing, 42, "trailing garbage");

    // the reader thread survived all five: the same connection still gets
    // typed application-level answers
    raw.send_frame(encode_request(&WireRequest {
        id: 5,
        engine: EngineKind::CipherPrune,
        nonce: 1,
        deadline_ms: 0,
        ids: vec![],
    }))
    .expect("send");
    match decode_response(&raw.recv_frame().expect("recv")).expect("decode") {
        WireResponse::Rejected { id, code, .. } => {
            assert_eq!((id, code), (5, RejectCode::EmptyInput));
        }
        other => panic!("expected Rejected(EmptyInput), got {other:?}"),
    }
    assert_eq!(server.stats().shed_rejected.load(Ordering::SeqCst), 6);
    drop(raw);
    server.shutdown();
}

/// A client that vanishes with work in flight neither hangs the server nor
/// contaminates other connections: its queued job is cancelled at dispatch,
/// and a later client on the same shard gets a normal, bit-identical result.
#[test]
fn severed_connection_cancels_own_work_only() {
    let model = tiny_model();
    let policy = BatchPolicy {
        max_batch: 8,
        linger: Duration::from_millis(150),
        min_bucket: 8,
        max_tokens: 32,
    };
    let cfg = ServeConfig { shards: 1, policy, ..ServeConfig::for_tests() };
    let mut server = Server::start(model.clone(), cfg, "127.0.0.1:0", "127.0.0.1:0")
        .expect("server start");
    let addr = server.addr().to_string();
    let ids = sample_ids(17);
    let kind = EngineKind::CipherPrune;

    // A: send then vanish before the linger releases the batch
    {
        let mut a = ServingClient::connect_retry(&addr, Duration::from_secs(5)).expect("A");
        a.send(&WireRequest { id: 1, engine: kind, nonce: 71, deadline_ms: 0, ids: ids.clone() })
            .expect("send");
        // dropped here: connection severed with the job still queued
    }
    std::thread::sleep(Duration::from_millis(30));

    // B: same shard, same bucket — must be served normally
    let mut b = ServingClient::connect_retry(&addr, Duration::from_secs(5)).expect("B");
    let resp = b
        .call(&WireRequest { id: 2, engine: kind, nonce: 72, deadline_ms: 0, ids: ids.clone() })
        .expect("B call");
    let WireResponse::Result { id, logits, .. } = resp else {
        panic!("B expected a Result, got {resp:?}");
    };
    assert_eq!(id, 2);
    let mut reference =
        Session::start(model, EngineConfig::for_tests(kind).seed(shard_seed(0, kind, 0)))
            .expect("reference session");
    let want = reference
        .infer_batch(&[BlockRun { nonce: 72, ids }])
        .expect("reference infer")
        .pop()
        .unwrap();
    assert_eq!(logits, want.logits, "survivor's result is unaffected by the severed peer");

    server.shutdown();
    let stats = server.stats();
    assert_eq!(stats.cancelled.load(Ordering::SeqCst), 1, "A's job cancelled, nothing else");
    assert_eq!(stats.completed.load(Ordering::SeqCst), 1);
    assert_eq!(stats.failed.load(Ordering::SeqCst), 0);
    assert_eq!(stats.queue_depth.load(Ordering::SeqCst), 0);
}

/// The `serve-clients` subcommand end-to-end as an OS process: announce the
/// bound addresses, serve real clients, exit 0 after `--max-requests`.
#[test]
fn serve_clients_subcommand_over_loopback() {
    let bin = env!("CARGO_BIN_EXE_cipherprune");
    let mut child = Command::new(bin)
        .args([
            "serve-clients",
            "--model",
            "tiny",
            "--he-n",
            "128",
            "--listen",
            "127.0.0.1:0",
            "--metrics",
            "127.0.0.1:0",
            "--shards",
            "2",
            "--linger-ms",
            "5",
            "--threads",
            "1",
            "--max-requests",
            "2",
        ])
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn serve-clients");
    let mut stdout = BufReader::new(child.stdout.take().expect("stdout"));
    let mut addr = String::new();
    for _ in 0..50 {
        let mut line = String::new();
        if stdout.read_line(&mut line).expect("read stdout") == 0 {
            break;
        }
        if let Some(rest) = line.trim().strip_prefix("listening on ") {
            addr = rest.to_string();
            break;
        }
    }
    assert!(!addr.is_empty(), "server must announce its listen address");

    let ids = sample_ids(17);
    let mut c = ServingClient::connect_retry(&addr, Duration::from_secs(10)).expect("connect");
    for id in 1..=2u64 {
        let req = WireRequest {
            id,
            engine: EngineKind::CipherPrune,
            nonce: 90 + id,
            deadline_ms: 0,
            ids: ids.clone(),
        };
        match c.call(&req).expect("call") {
            WireResponse::Result { id: rid, logits, .. } => {
                assert_eq!(rid, id);
                assert!(!logits.is_empty());
            }
            other => panic!("expected Result, got {other:?}"),
        }
    }
    drop(c);

    let status = child.wait().expect("wait serve-clients");
    let mut rest = String::new();
    stdout.read_to_string(&mut rest).expect("drain stdout");
    assert!(status.success(), "serve-clients must exit 0; tail: {rest}");
    assert!(rest.contains("completed=2"), "summary line reports the served requests: {rest}");
}
