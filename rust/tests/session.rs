//! Session-lifecycle integration tests: prepared models, reusable two-party
//! sessions, and the router's per-kind session cache.
//!
//! The contract under test: `Session::infer` is online-only (weight encoding
//! and key/base-OT setup happen before it), a session's first request is
//! bit-identical to the one-shot `run_inference` shim (same seed → same
//! randomness), and later requests through the same session are *exactly*
//! reproducible — aligned truncation (PR 3) removed the ±1-LSB
//! probabilistic-truncation drift that used to accumulate across a
//! session's randomness streams.

use std::sync::Arc;
use std::time::Duration;

use cipherprune::coordinator::{
    run_inference, BatchPolicy, EngineConfig, EngineKind, InferenceRequest,
    PreparedModel, Router, RouterConfig, Session,
};
use cipherprune::net::TransportSpec;
use cipherprune::nn::{ModelConfig, ModelWeights, Workload};

fn tiny_setup() -> (Arc<ModelWeights>, Vec<usize>) {
    let cfg = ModelConfig::tiny();
    let w = ModelWeights::salient(&cfg, 42);
    let ids = Workload::qnli_like(&cfg, 8).batch(1, 17)[0].ids.clone();
    (Arc::new(w), ids)
}

/// ≥3 requests through one session per engine kind: request 1 must equal the
/// one-shot path exactly, and — with aligned truncation — requests 2–3 must
/// reproduce it *exactly* too, despite reusing keys/base OTs at advanced
/// stream positions. Per-request wall time excludes weight encoding and
/// session setup by construction (both happen before `infer`).
#[test]
fn session_reuse_matches_one_shot_for_every_kind() {
    let (w, ids) = tiny_setup();
    for kind in EngineKind::private_engines() {
        let cfg = EngineConfig::for_tests(kind);
        let one_shot = run_inference(&cfg, &w, &ids);
        let model = Arc::new(PreparedModel::prepare(w.clone()));
        let mut session = Session::start(model, cfg).expect("session start");
        assert!(session.setup_stats().bytes > 0, "{kind:?}: setup communicates");
        let r1 = session.infer(&ids).expect("infer");
        assert_eq!(
            r1.logits, one_shot.logits,
            "{kind:?}: fresh session replays the one-shot randomness"
        );
        // setup traffic is not billed to the request
        assert!(r1.total_stats().bytes < one_shot.total_stats().bytes);
        for req in 2..=3 {
            let r = session.infer(&ids).expect("infer");
            assert_eq!(
                r.logits, one_shot.logits,
                "{kind:?} request {req}: aligned truncation makes repeats exact"
            );
            for (ls, os) in r.layer_stats.iter().zip(&one_shot.layer_stats) {
                assert_eq!(ls.n_in, os.n_in, "{kind:?} request {req} n_in");
                assert_eq!(ls.n_kept, os.n_kept, "{kind:?} request {req} n_kept");
            }
            assert!(r.total_stats().bytes > 0);
        }
        assert_eq!(session.runs(), 3);
    }
}

/// Per-request phase traffic from a reused session matches the one-shot
/// request's online traffic (the transcript delta bookkeeping is exact).
#[test]
fn session_request_traffic_is_per_request() {
    let (w, ids) = tiny_setup();
    let cfg = EngineConfig::for_tests(EngineKind::CipherPrune);
    let model = Arc::new(PreparedModel::prepare(w));
    let mut session = Session::start(model, cfg).expect("session start");
    let r1 = session.infer(&ids).expect("infer");
    let r2 = session.infer(&ids).expect("infer");
    // same input, same engine → same protocol structure and (deterministic
    // message framing) the same online byte count
    assert_eq!(r1.total_stats().bytes, r2.total_stats().bytes);
    assert_eq!(r1.stats_by_prefix("softmax").bytes, r2.stats_by_prefix("softmax").bytes);
    // per-layer harvest works on the delta
    assert!(r2.layer_stats[0].softmax_bytes > 0);
    assert!(r2.layer_stats[0].gelu_bytes > 0);
}

/// The plaintext oracle also runs behind the session API, with the same
/// masked padding semantics as the private engines.
#[test]
fn plaintext_session_serves_requests() {
    let (w, ids) = tiny_setup();
    let model = Arc::new(PreparedModel::prepare(w.clone()));
    let mut session = Session::start(model, EngineConfig::for_tests(EngineKind::Plaintext))
        .expect("session start");
    let r = session.infer(&ids).expect("infer");
    let want =
        cipherprune::nn::forward_masked(&w, &ids, &cipherprune::nn::ForwardOptions::plain());
    assert_eq!(r.logits, want.logits);
    assert_eq!(session.setup_wall_s(), 0.0);
}

/// Serving two sequential requests encodes `RingWeights` exactly once and
/// reuses one cached session (the prep counters in metrics prove it).
#[test]
fn router_prepares_model_once_across_requests() {
    let (w, _) = tiny_setup();
    let mut router = Router::new(
        w,
        RouterConfig {
            policy: BatchPolicy {
                max_batch: 1,
                linger: Duration::from_millis(0),
                min_bucket: 8,
                max_tokens: 64,
            },
            workers: 2,
            he_n: 128,
            schedule: None,
            threads: None,
            transport: TransportSpec::Mem,
            ..Default::default()
        },
    );
    let cfg = ModelConfig::tiny();
    let wl = Workload::qnli_like(&cfg, 8);
    for (i, s) in wl.batch(2, 5).into_iter().enumerate() {
        router.submit(InferenceRequest::new(i as u64, s.ids, EngineKind::CipherPrune)).unwrap();
        let resp = router.step();
        assert_eq!(resp.len(), 1, "max_batch=1, linger=0 → immediate release");
    }
    assert_eq!(router.metrics.model_preps, 1, "weights encoded exactly once");
    assert_eq!(router.metrics.session_setups, 1, "second request reused the session");
    assert_eq!(router.cached_sessions(EngineKind::CipherPrune), 1);
    assert_eq!(router.metrics.get("cipherprune").unwrap().runs, 2);
}
