//! Scalar ≡ SIMD bit-identity: the AVX2 kernels in `he::simd` / `ot::simd`
//! must produce byte-for-byte the same outputs as the scalar reference code
//! they replace — same lazy-reduction bounds, same final reductions — so
//! ciphertexts, OT rows, transcripts, and digests never depend on the
//! dispatch decision.
//!
//! The kernel-level tests force both paths explicitly through the
//! `*_with(…, use_simd)` twins and `try_*` entry points, which gate on
//! hardware support only — they stay meaningful even under the
//! `CIPHERPRUNE_SIMD=off` CI job (the env var controls the *default*
//! dispatch, not a forced path). On a host without AVX2 the `try_*` calls
//! return `false` and the identity tests pass vacuously (the portable
//! fallback IS the reference). Inputs include adversarial vectors at the
//! lazy-reduction boundaries (q−1, 2q−1, 4q−1 pre-reduction) — the values
//! where an off-by-one in the vectorized conditional subtractions or the
//! `mul_epu32` carry folding would show.
//!
//! The one test that toggles the process-wide dispatch switch
//! (`session_digest_pinned_across_dispatch`) is safe to run concurrently
//! with the rest of the binary precisely because of the property under
//! test: both settings compute identical bits.

use std::sync::Arc;

use cipherprune::coordinator::{EngineConfig, EngineKind, PreparedModel, Session};
use cipherprune::he::bfv::{
    decrypt, decrypt_with_scratch, encrypt, BfvContext, Ciphertext, Ctx, PtNtt, RnsPoly,
    SecretKey,
};
use cipherprune::he::ntt::{mul_mod, mul_mod_shoup, mul_mod_shoup_lazy, shoup, NttTable};
use cipherprune::he::params::{NPRIMES, PRIMES, PSI_16384};
use cipherprune::he::simd;
use cipherprune::nn::{ModelConfig, ModelWeights, Workload};
use cipherprune::ot::{simd as ot_simd, transpose64_scalar};
use cipherprune::util::{WorkerPool, Xoshiro256};

/// NTT table for prime `i`, ring degree `n` (primitive 2n-th root derived
/// from the 16384-th root by squaring).
fn table(i: usize, n: usize) -> NttTable {
    let q = PRIMES[i];
    let mut psi = PSI_16384[i];
    let mut order = 16384usize;
    while order > 2 * n {
        psi = mul_mod(psi, psi, q);
        order /= 2;
    }
    NttTable::new(q, n, psi)
}

/// Adversarial forward-NTT input: boundary values of the lazy [0, 4q)
/// domain up front, the rest uniform in [0, 4q).
fn adversarial_4q(q: u64, n: usize, seed: u64) -> Vec<u64> {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut a: Vec<u64> = (0..n).map(|_| rng.below(4 * q)).collect();
    a[0] = q - 1;
    a[1] = 2 * q - 1;
    a[2] = 4 * q - 1;
    a[3] = 0;
    a
}

#[test]
fn forward_ntt_identity_all_primes() {
    if !simd::avx2_available() {
        return; // scalar is the only path — nothing to compare
    }
    for i in 0..NPRIMES {
        let tb = table(i, 256);
        let q = tb.q;
        for seed in 0..4u64 {
            // canonical inputs (< q) and lazy-domain inputs (< 4q)
            let mut rng = Xoshiro256::seed_from_u64(100 + seed);
            let inputs = [
                (0..256).map(|_| rng.below(q)).collect::<Vec<u64>>(),
                adversarial_4q(q, 256, 200 + seed),
            ];
            for a0 in inputs {
                let mut scalar = a0.clone();
                let mut vector = a0.clone();
                tb.forward_with(&mut scalar, false);
                assert!(simd::try_forward(&tb, &mut vector));
                assert_eq!(scalar, vector, "prime {i} seed {seed}");
                assert!(scalar.iter().all(|&v| v < q), "not canonical");
            }
        }
    }
}

#[test]
fn inverse_ntt_identity_all_primes() {
    if !simd::avx2_available() {
        return;
    }
    for i in 0..NPRIMES {
        let tb = table(i, 256);
        let q = tb.q;
        for seed in 0..4u64 {
            // inverse accepts the lazy [0, 2q) domain; pin its boundaries
            let mut rng = Xoshiro256::seed_from_u64(300 + seed);
            let mut a0: Vec<u64> = (0..256).map(|_| rng.below(2 * q)).collect();
            a0[0] = q - 1;
            a0[1] = 2 * q - 1;
            a0[2] = 0;
            let mut scalar = a0.clone();
            let mut vector = a0;
            tb.inverse_with(&mut scalar, false);
            assert!(simd::try_inverse(&tb, &mut vector));
            assert_eq!(scalar, vector, "prime {i} seed {seed}");
            assert!(scalar.iter().all(|&v| v < q), "not canonical");
        }
    }
}

#[test]
fn ntt_roundtrip_under_forced_simd() {
    let tb = table(0, 512);
    let mut rng = Xoshiro256::seed_from_u64(7);
    let orig: Vec<u64> = (0..512).map(|_| rng.below(tb.q)).collect();
    let mut a = orig.clone();
    // forced-simd entry points fall back to scalar off-AVX2 hosts, so the
    // roundtrip contract holds everywhere
    tb.forward_with(&mut a, true);
    assert_ne!(a, orig);
    tb.inverse_with(&mut a, true);
    assert_eq!(a, orig);
}

#[test]
fn mul_acc_lazy_identity_with_boundaries() {
    if !simd::avx2_available() {
        return;
    }
    for i in 0..NPRIMES {
        let q = PRIMES[i];
        let two_q = 2 * q;
        let n = 259; // deliberately not a multiple of 4: exercises the tail
        let mut rng = Xoshiro256::seed_from_u64(400 + i as u64);
        let mut dst0: Vec<u64> = (0..n).map(|_| rng.below(two_q)).collect();
        let src: Vec<u64> = (0..n).map(|_| rng.below(q)).collect();
        let mut w: Vec<u64> = (0..n).map(|_| rng.below(q)).collect();
        // boundary lane values: dst at the top of [0, 2q), operands at q−1
        dst0[0] = two_q - 1;
        dst0[1] = two_q - 1;
        w[1] = q - 1;
        let wp: Vec<u64> = w.iter().map(|&x| shoup(x, q)).collect();
        let mut vector = dst0.clone();
        assert!(simd::try_mul_acc_lazy(&mut vector, &src, &w, &wp, q));
        // scalar reference: the exact mul_pt_accumulate_lazy formula
        let mut scalar = dst0;
        for j in 0..n {
            let p = mul_mod_shoup_lazy(src[j], w[j], wp[j], q);
            let s = scalar[j] + p;
            scalar[j] = if s >= two_q { s - two_q } else { s };
        }
        assert_eq!(scalar, vector, "prime {i}");
        assert!(vector.iter().all(|&v| v < two_q), "lazy bound violated");
    }
}

#[test]
fn mul_shoup_const_identity_matches_mul_mod() {
    if !simd::avx2_available() {
        return;
    }
    for i in 0..NPRIMES {
        let q = PRIMES[i];
        let n = 261; // tail lanes again
        let mut rng = Xoshiro256::seed_from_u64(500 + i as u64);
        let mut vals: Vec<u64> = (0..n).map(|_| rng.below(q)).collect();
        vals[0] = q - 1;
        vals[1] = 0;
        let y = rng.below(q);
        let yp = shoup(y, q);
        let expect: Vec<u64> = vals.iter().map(|&x| mul_mod(x, y, q)).collect();
        let strict: Vec<u64> =
            vals.iter().map(|&x| mul_mod_shoup(x, y, yp, q)).collect();
        assert_eq!(expect, strict, "Shoup ≠ plain mul_mod (prime {i})");
        assert!(simd::try_mul_shoup_const(&mut vals, y, yp, q));
        assert_eq!(vals, expect, "prime {i}");
    }
}

#[test]
fn ciphertext_ops_identical_under_both_dispatches() {
    // end-to-end HE identity through the real entry points, both dispatch
    // decisions forced per call (no global toggles): encode, a lazy
    // accumulate chain, and decrypt
    fn setup(n: usize) -> (Ctx, SecretKey, Xoshiro256) {
        let ctx = BfvContext::new(n);
        let mut rng = Xoshiro256::seed_from_u64(42);
        let sk = SecretKey::gen(&ctx, &mut rng);
        (ctx, sk, rng)
    }
    let (ctx, sk, mut rng) = setup(256);
    let mut acc_scalar = Ciphertext::zero_like(&ctx);
    let mut acc_simd = Ciphertext::zero_like(&ctx);
    for step in 0..3 {
        let m: Vec<u64> = (0..ctx.n).map(|_| rng.next_u64()).collect();
        let mut w = vec![0u64; ctx.n];
        for wi in w.iter_mut().take(8) {
            *wi = ((rng.next_u64() % 16384) as i64 - 8192) as u64;
        }
        w[step] = w[step].wrapping_add(1);
        let ct = encrypt(&ctx, &sk, &m, &mut rng);
        let pt = PtNtt::encode(&ctx, &w);
        acc_scalar.mul_pt_accumulate_lazy_with(&ct, &pt, false);
        acc_simd.mul_pt_accumulate_lazy_with(&ct, &pt, true);
    }
    acc_scalar.normalize();
    acc_simd.normalize();
    assert_eq!(acc_scalar.c0, acc_simd.c0, "c0 residues");
    assert_eq!(acc_scalar.c1, acc_simd.c1, "c1 residues");
    // decrypt honors the global switch inside decrypt_with_scratch; force
    // both settings and compare (restoring auto after)
    simd::set_enabled(false);
    let mut scratch = RnsPoly::zero(&ctx, true);
    let plain = decrypt_with_scratch(&ctx, &sk, &acc_scalar, WorkerPool::single(), &mut scratch);
    simd::set_enabled(true);
    let vec_path = decrypt(&ctx, &sk, &acc_simd);
    simd::set_auto();
    assert_eq!(plain, vec_path, "decrypted coefficients");
}

#[test]
fn transpose64_identity_and_roundtrip() {
    let mut rng = Xoshiro256::seed_from_u64(600);
    for trial in 0..8 {
        let mut a = [0u64; 64];
        for v in a.iter_mut() {
            *v = rng.next_u64();
        }
        // boundary patterns on the first trials
        if trial == 0 {
            a = [u64::MAX; 64];
        } else if trial == 1 {
            a = [0u64; 64];
            a[0] = 1; // single bit walks to (63, 63) under the row-reversal map
        }
        let orig = a;
        let mut scalar = a;
        transpose64_scalar(&mut scalar);
        if ot_simd::try_transpose64(&mut a) {
            assert_eq!(scalar, a, "trial {trial}");
            // transpose is an involution under the (r,c)→(63−c,63−r) map
            assert!(ot_simd::try_transpose64(&mut a));
            assert_eq!(a, orig, "roundtrip, trial {trial}");
        } else {
            // no AVX2: the dispatching entry point must still be scalar
            let mut b = orig;
            cipherprune::ot::transpose64(&mut b);
            assert_eq!(scalar, b, "trial {trial}");
        }
    }
}

/// The whole stack, both dispatch decisions: a full `Session::infer` with
/// SIMD forced off vs forced on must produce identical logits AND an
/// identical wire-content transcript digest. This is the PR's headline
/// contract — vectorization is invisible to the protocol. (On a non-AVX2
/// host `.simd(true)` clamps to scalar and the comparison is trivially
/// true, which is exactly the portable claim.)
#[test]
fn session_digest_pinned_across_dispatch() {
    let cfg = ModelConfig::tiny();
    let w = Arc::new(ModelWeights::salient(&cfg, 42));
    let ids = Workload::qnli_like(&cfg, 8).batch(1, 17)[0].ids.clone();

    let mut baseline: Option<(Vec<f64>, u64, [u64; 2])> = None;
    for &on in &[false, true] {
        let ec = EngineConfig::for_tests(EngineKind::CipherPrune).simd(on);
        let model = Arc::new(PreparedModel::prepare(w.clone()));
        let mut session = Session::start(model, ec).expect("session start");
        let r = session.infer(&ids).expect("infer");
        let cur = (r.logits.clone(), r.total_stats().bytes, session.transcript_digest());
        match &baseline {
            None => baseline = Some(cur),
            Some(b) => {
                assert_eq!(b.0, cur.0, "logits differ with simd={on}");
                assert_eq!(b.1, cur.1, "request bytes differ with simd={on}");
                assert_eq!(b.2, cur.2, "transcript digest differs with simd={on}");
            }
        }
    }
    simd::set_auto();
}
