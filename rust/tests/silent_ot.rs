//! Offline-bandwidth stack, end to end: the silent-OT extension, the
//! trusted-dealer download, and the persistent pool spill must all be
//! *invisible* to the online protocol — identical logits and prune/reduce
//! decisions across every {ExtMode} × {TripleMode} × {fresh, spilled}
//! combination, with the silent extension crushing offline ROT bytes and
//! the spill format failing typed (never panicking) on corruption.

use std::path::PathBuf;
use std::sync::Arc;

use cipherprune::coordinator::{
    dealer_serve_pair, BlockRun, EngineConfig, EngineKind, PreparedModel, PreprocDemand,
    Session,
};
use cipherprune::gates::preproc::{PreprocSnapshot, SpillError};
use cipherprune::gates::TripleMode;
use cipherprune::net::{TcpTransport, TransportSpec};
use cipherprune::ot::ExtMode;

fn setup() -> (Arc<PreparedModel>, Vec<BlockRun>) {
    let cfg = cipherprune::nn::ModelConfig::tiny();
    let w = Arc::new(cipherprune::nn::ModelWeights::salient(&cfg, 42));
    let model = Arc::new(PreparedModel::prepare(w));
    let items: Vec<BlockRun> = cipherprune::nn::Workload::qnli_like(&cfg, 12)
        .batch(2, 7)
        .into_iter()
        .enumerate()
        .map(|(i, s)| BlockRun { nonce: 1 + i as u64, ids: s.ids })
        .collect();
    (model, items)
}

fn ec() -> EngineConfig {
    EngineConfig::for_tests(EngineKind::CipherPrune)
}

/// Fresh scratch directory under the system tempdir (unique per test tag;
/// removed by the test on success).
fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("cipherprune-silent-ot-{}-{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("scratch dir");
    dir
}

fn decisions(r: &cipherprune::coordinator::RunResult) -> Vec<(usize, usize)> {
    r.layer_stats.iter().map(|l| (l.n_kept, l.n_high)).collect()
}

/// The headline matrix: every way of obtaining correlated randomness —
/// IKNP or silent extension, OT-generated or dealer-mode triples, freshly
/// filled or spilled-to-disk-and-reloaded pools — serves the same batch
/// with bit-identical logits and pruning decisions.
#[test]
fn mode_combos_serve_bit_identical_results() {
    let (model, items) = setup();
    let lens: Vec<usize> = items.iter().map(|b| b.ids.len()).collect();
    let mut base = Session::start(model.clone(), ec()).expect("baseline session");
    let want = base.infer_batch(&items).expect("baseline infer");

    for ext in ExtMode::ALL {
        for tm in [TripleMode::Ot, TripleMode::Dealer] {
            for spilled in [false, true] {
                let tag = format!("{ext:?}-{tm:?}-spilled={spilled}");
                let cfg = ec().ext_mode(ext).triple_mode(tm);
                let mut s =
                    Session::start(model.clone(), cfg.clone()).expect("session");
                s.preprocess(&lens).expect("preprocess");
                if spilled {
                    let dir = scratch(&tag.replace('=', "-"));
                    s.spill_preproc(&dir).expect("spill");
                    // a brand-new session loads the spill instead of filling
                    s = Session::start(model.clone(), cfg).expect("reload session");
                    assert!(
                        s.load_preproc(&dir).expect("load"),
                        "{tag}: both spill files must load"
                    );
                    let _ = std::fs::remove_dir_all(&dir);
                }
                let got = s.infer_batch(&items).expect("infer");
                for (w, g) in want.iter().zip(&got) {
                    assert_eq!(w.logits, g.logits, "{tag}: logits must be bit-identical");
                    assert_eq!(decisions(w), decisions(g), "{tag}: decisions must match");
                }
                let [p0, _] = s.preproc_reports();
                assert_eq!(p0.triples.inline, 0, "{tag}: pools must cover the run");
                assert!(p0.triples.drained > 0, "{tag}: the run must drain the pools");
            }
        }
    }
}

/// Pool sizes {1, 2, max} per extension mode: undersized pools serve what
/// they can and fall back inline mid-batch without changing a single bit
/// of the output; the full dry-run demand covers the run exactly.
#[test]
fn undersized_pools_fall_back_inline_per_mode() {
    let (model, items) = setup();
    let one = vec![items[0].clone()];
    let mut base = Session::start(model.clone(), ec()).expect("baseline");
    let want = base.infer_batch(&one).expect("baseline infer");

    for ext in ExtMode::ALL {
        let full = {
            let s = Session::start(model.clone(), ec().ext_mode(ext)).expect("probe");
            s.preproc_demand(&[one[0].ids.len()])
        };
        let tiny = |k: u64| PreprocDemand {
            triples: k,
            rot_p0s: k,
            rot_p1s: k,
            pad_words: 0,
        };
        for (label, demand) in
            [("1", tiny(1)), ("2", tiny(2)), ("max", full.clone())]
        {
            let mut s =
                Session::start(model.clone(), ec().ext_mode(ext)).expect("session");
            s.preprocess_with(&demand).expect("preprocess");
            let got = s.infer_batch(&one).expect("infer");
            assert_eq!(
                want[0].logits, got[0].logits,
                "{ext:?} pool size {label}: fallback must stay bit-identical"
            );
            let [p0, _] = s.preproc_reports();
            assert_eq!(p0.triples.filled, demand.triples, "{ext:?} {label}: fill == demand");
            assert_eq!(p0.rot_send.filled, demand.rot_p0s);
            if label == "max" {
                assert_eq!(p0.triples.inline, 0, "{ext:?}: dry-run demand covers the run");
                assert_eq!(p0.rot_send.inline, 0);
            } else {
                assert!(
                    p0.triples.inline > 0,
                    "{ext:?} {label}: an undersized pool must fall back inline"
                );
            }
        }
    }
}

/// The point of the silent extension: offline ROT bytes on the party link
/// drop by well over the 8× the bench tripwire demands (the seed-exchange
/// plus sparse-correction traffic replaces the dense IKNP u-matrix).
#[test]
fn silent_extension_crushes_offline_rot_bytes() {
    let (model, _) = setup();
    let rots = PreprocDemand { triples: 0, rot_p0s: 1 << 14, rot_p1s: 1 << 14, pad_words: 0 };
    let offline_bytes = |ext: ExtMode| -> u64 {
        let mut s = Session::start(model.clone(), ec().ext_mode(ext)).expect("session");
        s.preprocess_with(&rots).expect("preprocess");
        s.phase_stats()
            .iter()
            .filter(|(name, _)| name.starts_with("preproc"))
            .map(|(_, st)| st.bytes)
            .sum()
    };
    let iknp = offline_bytes(ExtMode::Iknp);
    let silent = offline_bytes(ExtMode::Silent);
    assert!(iknp > 0 && silent > 0, "both fills must communicate ({iknp} / {silent})");
    assert!(
        silent * 8 <= iknp,
        "silent fill must cut offline ROT bytes ≥8×: silent {silent} vs iknp {iknp}"
    );
}

/// Transport invariance of both extension backends at a fixed config: the
/// whole offline+online wire content (per-endpoint digests) is identical
/// on mem and real loopback TCP.
#[test]
fn pool_fills_are_transport_invariant_per_mode() {
    let (model, items) = setup();
    let lens: Vec<usize> = items.iter().map(|b| b.ids.len()).collect();
    for ext in ExtMode::ALL {
        let run = |transport: TransportSpec| {
            let cfg = ec().ext_mode(ext).transport(transport);
            let mut s = Session::start(model.clone(), cfg).expect("session");
            s.preprocess(&lens).expect("preprocess");
            let rs = s.infer_batch(&items).expect("infer");
            let logits: Vec<Vec<f64>> = rs.iter().map(|r| r.logits.clone()).collect();
            (logits, s.transcript_digest())
        };
        let mem = run(TransportSpec::Mem);
        let tcp = run(TransportSpec::TcpLoopback);
        assert_eq!(mem.0, tcp.0, "{ext:?}: logits must not depend on the transport");
        assert_eq!(mem.1, tcp.1, "{ext:?}: wire content must not depend on the transport");
    }
}

/// Spill → load → drain bit-identity: a reloaded session holds exactly the
/// pool entries the spilling session held, so its run drains the same
/// counts and reproduces the same bits.
#[test]
fn spill_load_drain_is_bit_identical() {
    let (model, items) = setup();
    let lens: Vec<usize> = items.iter().map(|b| b.ids.len()).collect();
    let dir = scratch("roundtrip");

    let mut a = Session::start(model.clone(), ec()).expect("session A");
    a.preprocess(&lens).expect("preprocess");
    a.spill_preproc(&dir).expect("spill");
    let want = a.infer_batch(&items).expect("infer A");
    let [a0, _] = a.preproc_reports();

    let mut b = Session::start(model.clone(), ec()).expect("session B");
    assert!(b.load_preproc(&dir).expect("load"), "spill files must load");
    {
        let [b0, _] = b.preproc_reports();
        assert_eq!(b0.triples_avail, a0.triples.filled, "load banks the full spill");
        assert_eq!(b0.rot_send_avail, a0.rot_send.filled);
        assert_eq!(b0.rot_recv_avail, a0.rot_recv.filled);
    }
    let got = b.infer_batch(&items).expect("infer B");
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.logits, g.logits, "reloaded pools must reproduce the run");
        assert_eq!(w.total_stats().bytes, g.total_stats().bytes);
    }
    let [b0, _] = b.preproc_reports();
    assert_eq!(b0.triples.drained, a0.triples.drained, "identical drains");
    assert_eq!(b0.rot_send.drained, a0.rot_send.drained);
    assert_eq!(b0.triples.inline, 0, "the loaded pools cover the run");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A corrupted or truncated spill file surfaces as a typed [`SpillError`]
/// inside the session error — no panic, nothing imported (the parties'
/// pools stay in lockstep), and the session keeps serving.
#[test]
fn corrupt_spill_is_a_typed_error_not_a_panic() {
    let (model, items) = setup();
    let one = vec![items[0].clone()];
    let lens = vec![one[0].ids.len()];
    let dir = scratch("corrupt");

    let mut a = Session::start(model.clone(), ec()).expect("session A");
    a.preprocess(&lens).expect("preprocess");
    a.spill_preproc(&dir).expect("spill");
    let want = a.infer_batch(&one).expect("infer A");

    let p0_file = dir.join(PreprocSnapshot::file_name(0, a.config().seed));
    let clean = std::fs::read(&p0_file).expect("spill file");

    // bit-flip in the body → checksum failure
    let mut evil = clean.clone();
    let mid = evil.len() / 2;
    evil[mid] ^= 0x40;
    std::fs::write(&p0_file, &evil).expect("write corrupt");
    let mut b = Session::start(model.clone(), ec()).expect("session B");
    let err = b.load_preproc(&dir).expect_err("corrupt spill must be an error");
    assert!(
        matches!(err.downcast_ref::<SpillError>(), Some(SpillError::Checksum { .. })),
        "typed checksum error, got: {err:#}"
    );

    // truncation → typed truncation/checksum error, still no panic
    std::fs::write(&p0_file, &clean[..clean.len() / 3]).expect("write truncated");
    let err = b.load_preproc(&dir).expect_err("truncated spill must be an error");
    assert!(err.downcast_ref::<SpillError>().is_some(), "typed error, got: {err:#}");

    // nothing was imported and the session still serves, bit-identically
    let [b0, _] = b.preproc_reports();
    assert_eq!(b0.triples_avail, 0, "a failed load must import nothing");
    let got = b.infer_batch(&one).expect("infer after failed load");
    assert_eq!(want[0].logits, got[0].logits);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Trusted-dealer topology in-process: a dealer thread streams both
/// parties' pool shares over real TCP, the session's offline phase becomes
/// a pure download (zero preproc bytes on the party link), and the online
/// run is bit-identical to a self-preprocessed session's.
#[test]
fn dealer_download_matches_self_preprocessed_run() {
    let (model, items) = setup();
    let lens: Vec<usize> = items.iter().map(|b| b.ids.len()).collect();

    let mut sp = Session::start(model.clone(), ec()).expect("self-preproc session");
    let demand = sp.preprocess(&lens).expect("preprocess");
    let want = sp.infer_batch(&items).expect("infer");

    let (listener, addr) = TcpTransport::bind("127.0.0.1:0").expect("dealer bind");
    let dealer = std::thread::spawn(move || dealer_serve_pair(&listener));

    let cfg = ec().dealer(&addr.to_string());
    let mut s = Session::start(model.clone(), cfg).expect("dealer session");
    s.preprocess(&lens).expect("dealer download");
    let report = dealer.join().expect("dealer thread").expect("dealer serve");
    assert_eq!(report.triples, demand.triples, "dealer streamed the full demand");
    assert_eq!(report.rot_p0s, demand.rot_p0s);
    assert_eq!(report.rot_p1s, demand.rot_p1s);
    assert!(report.bytes > 0);

    // the party link itself carried no offline fill traffic — the offline
    // phase was a pure download on the dealer links
    let preproc_on_link: u64 = s
        .phase_stats()
        .iter()
        .filter(|(name, _)| name.starts_with("preproc"))
        .map(|(_, st)| st.bytes)
        .sum();
    assert_eq!(preproc_on_link, 0, "dealer offline must not touch the party link");

    let got = s.infer_batch(&items).expect("infer");
    for (w, g) in want.iter().zip(&got) {
        assert_eq!(w.logits, g.logits, "dealer pools must reproduce the run");
        assert_eq!(decisions(w), decisions(g));
    }
    let [p0, _] = s.preproc_reports();
    assert_eq!(p0.triples.inline, 0, "the download covered the whole run");
    assert!(p0.triples.drained > 0);
}
