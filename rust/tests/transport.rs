//! Transport-layer integration: the pluggable channel backends must be
//! protocol-invisible — same seed ⇒ identical logits, prune/reduce
//! decisions, transcript totals, and per-endpoint wire-content digests on
//! MemTransport, TcpTransport (real loopback sockets), and SimTransport —
//! while flight coalescing strictly reduces one-way trips, SimTransport's
//! injected delays agree with the analytic NetModel, a severed link fails
//! the request (typed error, poisoned session) instead of the process, and
//! the `cipherprune party` subcommand runs the protocol across two real OS
//! processes over loopback TCP.

use std::io::{BufRead, BufReader, Read};
use std::process::{Command, Stdio};
use std::sync::atomic::Ordering;
use std::sync::Arc;

use cipherprune::coordinator::{
    EngineConfig, EngineKind, PreparedModel, RunResult, Session,
};
use cipherprune::net::{
    new_transcript, Chan, CutTransport, MemTransport, NetModel, PhaseStats, TransportSpec,
};
use cipherprune::nn::{ModelConfig, ModelWeights, Workload};

fn tiny() -> (Arc<ModelWeights>, Vec<usize>) {
    let cfg = ModelConfig::tiny();
    let w = Arc::new(ModelWeights::salient(&cfg, 42));
    let ids = Workload::qnli_like(&cfg, 8).batch(1, 17)[0].ids.clone();
    (w, ids)
}

fn run_once(spec: TransportSpec, coalesce: bool) -> (RunResult, [u64; 2], PhaseStats) {
    let (w, ids) = tiny();
    let model = Arc::new(PreparedModel::prepare(w));
    let ec = EngineConfig::for_tests(EngineKind::CipherPrune)
        .transport(spec)
        .coalesce(coalesce);
    let mut s = Session::start(model, ec).expect("session start");
    let r = s.infer(&ids).expect("infer");
    let digest = s.transcript_digest();
    (r, digest, s.setup_stats())
}

fn assert_identical(
    (ra, da, sa): &(RunResult, [u64; 2], PhaseStats),
    (rb, db, sb): &(RunResult, [u64; 2], PhaseStats),
    what: &str,
) {
    assert_eq!(ra.logits, rb.logits, "{what}: logits");
    for (x, y) in ra.layer_stats.iter().zip(&rb.layer_stats) {
        assert_eq!(x.n_in, y.n_in, "{what}: n_in");
        assert_eq!(x.n_kept, y.n_kept, "{what}: prune decisions");
        assert_eq!(x.n_high, y.n_high, "{what}: reduce decisions");
        assert_eq!(x.swaps, y.swaps, "{what}: swaps");
    }
    assert_eq!(da, db, "{what}: per-endpoint wire-content digests");
    let (ta, tb) = (ra.total_stats(), rb.total_stats());
    assert_eq!(ta.bytes, tb.bytes, "{what}: online bytes");
    assert_eq!(ta.msgs, tb.msgs, "{what}: online msgs");
    assert_eq!(ta.flights, tb.flights, "{what}: online flights");
    assert_eq!(sa.bytes, sb.bytes, "{what}: setup bytes");
    assert_eq!(sa.msgs, sb.msgs, "{what}: setup msgs");
}

/// Real TCP over a loopback socket is byte-identical to the in-memory
/// substrate: the transport is below the framing/accounting layer.
#[test]
fn tcp_loopback_is_bit_identical_to_mem() {
    let mem = run_once(TransportSpec::Mem, true);
    let tcp = run_once(TransportSpec::TcpLoopback, true);
    assert_identical(&mem, &tcp, "tcp vs mem");
}

/// SimTransport (here with the zero-cost model, so the test stays fast) is
/// byte-identical too — delay injection sits below the accounting layer.
#[test]
fn sim_transport_is_bit_identical_to_mem() {
    let mem = run_once(TransportSpec::Mem, true);
    let sim = run_once(TransportSpec::Sim(NetModel::INSTANT), true);
    assert_identical(&mem, &sim, "sim vs mem");
}

/// Coalescing strictly reduces recorded flights — in total AND on at least
/// one multi-round protocol phase — while logits, decisions, bytes, msgs,
/// and wire digests stay identical.
#[test]
fn coalescing_strictly_reduces_flights_only() {
    let on = run_once(TransportSpec::Mem, true);
    let off = run_once(TransportSpec::Mem, false);
    // everything but flights is untouched
    assert_eq!(on.0.logits, off.0.logits);
    assert_eq!(on.1, off.1, "wire digests unchanged by coalescing");
    let (tc, tu) = (on.0.total_stats(), off.0.total_stats());
    assert_eq!(tc.bytes, tu.bytes);
    assert_eq!(tc.msgs, tu.msgs);
    assert!(
        tc.flights < tu.flights,
        "coalescing must reduce total flights ({} !< {})",
        tc.flights,
        tu.flights
    );
    // …and strictly on at least one individual phase
    let uncoalesced: std::collections::BTreeMap<&str, u64> =
        off.0.phases.iter().map(|(k, v)| (k.as_str(), v.flights)).collect();
    let reduced = on.0.phases.iter().any(|(k, v)| {
        uncoalesced.get(k.as_str()).map(|u| v.flights < *u).unwrap_or(false)
    });
    assert!(reduced, "at least one phase must lose flights to coalescing");
}

/// Measured wall time over SimTransport ≈ `NetModel::time` of the recorded
/// transcript, on a serial ping-pong where latency dominates compute.
#[test]
fn sim_delay_tracks_net_model() {
    let m = NetModel { name: "test", bandwidth_bps: 80e6, rtt_s: 16e-3 };
    let (mut a, mut b, t) = Chan::sim_pair(m);
    let rounds = 6usize;
    let h = std::thread::spawn(move || {
        for _ in 0..rounds {
            let v = b.recv_u64s();
            b.send_u64s(&v);
        }
        // trailing reply flushes when b drops here
    });
    let t0 = std::time::Instant::now();
    for i in 0..rounds {
        a.send_u64s(&vec![i as u64; 1000]);
        let _ = a.recv_u64s();
    }
    let wall = t0.elapsed().as_secs_f64();
    h.join().unwrap();
    let total = t.lock().unwrap().total();
    assert_eq!(total.flights as usize, 2 * rounds, "one frame per direction per round");
    let modeled = m.time(&total);
    assert!(
        wall >= 0.9 * modeled,
        "measured {wall:.4}s must not undershoot the model {modeled:.4}s"
    );
    assert!(
        wall <= 2.0 * modeled + 0.05,
        "measured {wall:.4}s strayed far above the model {modeled:.4}s"
    );
}

/// A severed link fails the request with a typed, readable error; the
/// session poisons (later requests fail fast) and the process survives.
#[test]
fn severed_link_fails_request_not_process() {
    let (w, ids) = tiny();
    let model = Arc::new(PreparedModel::prepare(w));
    let (ta, tb) = MemTransport::pair();
    let (cta, cut) = CutTransport::new(Box::new(ta));
    let ctb = CutTransport::wrapping(Box::new(tb), cut.clone());
    let t = new_transcript();
    let ca = Chan::over(Box::new(cta), 0, t.clone());
    let cb = Chan::over(Box::new(ctb), 1, t.clone());
    let ec = EngineConfig::for_tests(EngineKind::CipherPrune);
    let mut s = Session::start_over(model, ec, (ca, cb, t)).expect("session start");

    let ok = s.infer(&ids).expect("healthy link serves the request");
    assert_eq!(ok.logits.len(), 2);
    assert!(s.poisoned().is_none());

    cut.store(true, Ordering::SeqCst);
    let err = s.infer(&ids).expect_err("severed link must fail the request");
    let msg = format!("{err:#}");
    assert!(msg.contains("disconnected"), "typed NetError surfaced: {msg}");
    assert!(s.poisoned().is_some());

    let again = s.infer(&ids).expect_err("poisoned session fails fast");
    assert!(format!("{again:#}").contains("poisoned"));
}

/// A session whose transport is dead from the start reports a setup error
/// instead of panicking or hanging.
#[test]
fn dead_transport_fails_session_setup_cleanly() {
    let (w, _ids) = tiny();
    let model = Arc::new(PreparedModel::prepare(w));
    let (ta, tb) = MemTransport::pair();
    let (cta, cut) = CutTransport::new(Box::new(ta));
    let ctb = CutTransport::wrapping(Box::new(tb), cut.clone());
    cut.store(true, Ordering::SeqCst); // dead before the first byte
    let t = new_transcript();
    let ca = Chan::over(Box::new(cta), 0, t.clone());
    let cb = Chan::over(Box::new(ctb), 1, t.clone());
    let ec = EngineConfig::for_tests(EngineKind::CipherPrune);
    let err = Session::start_over(model, ec, (ca, cb, t))
        .expect_err("setup over a dead link must error");
    assert!(format!("{err:#}").contains("setup failed"), "{err:#}");
}

/// The real two-process topology: spawn `cipherprune party` twice (P0
/// listening on an ephemeral loopback port, P1 connecting), and check both
/// complete the same request stream. This is the full stack — processes,
/// sockets, handshake, framed coalesced wire protocol — in `cargo test`,
/// with no external network.
#[test]
fn two_process_party_subcommand_over_loopback() {
    let bin = env!("CARGO_BIN_EXE_cipherprune");
    let common = [
        "--model",
        "tiny",
        "--he-n",
        "128",
        "--requests",
        "2",
        "--seq",
        "8",
        "--seed",
        "7",
        "--threads",
        "1",
    ];
    let mut p0 = Command::new(bin)
        .args(["party", "--role", "p0", "--listen", "127.0.0.1:0"])
        .args(common)
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .expect("spawn P0");
    // P0 prints its ephemeral address before accepting
    let mut p0_stdout = BufReader::new(p0.stdout.take().expect("P0 stdout"));
    let mut addr = String::new();
    for _ in 0..50 {
        let mut line = String::new();
        if p0_stdout.read_line(&mut line).expect("read P0 stdout") == 0 {
            break;
        }
        if let Some(rest) = line.trim().strip_prefix("listening on ") {
            addr = rest.to_string();
            break;
        }
    }
    assert!(!addr.is_empty(), "P0 must announce its listen address");

    let p1 = Command::new(bin)
        .args(["party", "--role", "p1", "--connect", &addr])
        .args(common)
        .output()
        .expect("run P1");
    let p1_out = String::from_utf8_lossy(&p1.stdout).to_string()
        + &String::from_utf8_lossy(&p1.stderr);
    assert!(p1.status.success(), "P1 failed:\n{p1_out}");

    let mut p0_rest = String::new();
    p0_stdout.read_to_string(&mut p0_rest).expect("drain P0 stdout");
    let status = p0.wait().expect("wait P0");
    assert!(status.success(), "P0 failed:\n{p0_rest}");
    assert!(p0_rest.contains("pred"), "P0 prints predictions:\n{p0_rest}");
    assert!(p0_rest.contains("party P0 done"), "{p0_rest}");
    assert!(p1_out.contains("party P1 done"), "{p1_out}");
}
