//! Batched-vs-solo bit-consistency: the contract of the mask-aware fused
//! pipeline (see the coordinator docs on padding semantics).
//!
//! For any request, ALL of the following must produce identical logits and
//! identical per-layer `n_kept`/`n_high` trajectories — not merely close:
//!
//! 1. run alone at its real length,
//! 2. run alone padded to a power-of-two bucket,
//! 3. run inside a fused batch with other requests.
//!
//! (1) ≡ (2) is the padding bugfix: lengths are public, the session strips
//! the pad run, so the bucket cannot change the computation — the wire
//! transcript is byte-identical. (3) ≡ (1) is what aligned truncation buys:
//! every non-truncation gate is exact in reconstruction, and the canonical
//! per-(nonce, counter) truncation streams make the one inexact gate a
//! deterministic function of the reconstructed value, so a block inside a
//! fused run reconstructs exactly its solo values.

use std::sync::Arc;

use cipherprune::coordinator::{
    BatchPolicy, BlockRun, EngineConfig, EngineKind, InferenceRequest, PreparedModel,
    Router, RouterConfig, Session,
};
use cipherprune::net::TransportSpec;
use cipherprune::nn::{real_len, ModelConfig, ModelWeights, Workload, PAD_ID};

fn tiny_weights() -> Arc<ModelWeights> {
    Arc::new(ModelWeights::salient(&ModelConfig::tiny(), 42))
}

fn sample_ids(seed: u64) -> Vec<usize> {
    let cfg = ModelConfig::tiny();
    Workload::qnli_like(&cfg, 8).batch(1, seed)[0].ids.clone()
}

fn fresh_session(w: &Arc<ModelWeights>) -> Session {
    let model = Arc::new(PreparedModel::prepare(w.clone()));
    Session::start(model, EngineConfig::for_tests(EngineKind::CipherPrune))
        .expect("session start")
}

/// (1) ≡ (2): real length vs padded bucket — identical logits, identical
/// per-layer decisions, identical wire transcript.
#[test]
fn padded_solo_matches_real_length_bit_for_bit() {
    let w = tiny_weights();
    let ids = sample_ids(17);
    let real = real_len(&ids);
    let real_ids = ids[..real].to_vec();
    let mut padded = real_ids.clone();
    padded.resize(real + 9, PAD_ID); // an off-bucket pad run, why not

    let mut s_real = fresh_session(&w);
    let mut s_pad = fresh_session(&w);
    let a = s_real
        .infer_batch(&[BlockRun { nonce: 7, ids: real_ids }])
        .expect("infer")
        .pop()
        .unwrap();
    let b = s_pad
        .infer_batch(&[BlockRun { nonce: 7, ids: padded }])
        .expect("infer")
        .pop()
        .unwrap();

    assert_eq!(a.logits, b.logits, "bucket padding changed the logits");
    assert_eq!(a.layer_stats.len(), b.layer_stats.len());
    for (x, y) in a.layer_stats.iter().zip(&b.layer_stats) {
        assert_eq!(x.n_in, y.n_in);
        assert_eq!(x.n_kept, y.n_kept);
        assert_eq!(x.n_high, y.n_high);
        assert_eq!(x.swaps, y.swaps);
    }
    assert_eq!(a.layer_stats[0].n_in, real, "pipeline saw the real length");
    // strongest form: the two sessions exchanged identical bytes
    assert_eq!(
        s_real.transcript_digest(),
        s_pad.transcript_digest(),
        "stripping must make the padded run's transcript identical"
    );
}

/// (3) ≡ (1): a fused batch of mixed-length requests reproduces each
/// member's solo run exactly, for every engine kind that reaches the
/// two-party pipeline's pruning/reduction machinery.
#[test]
fn fused_batch_matches_solo_runs_bit_for_bit() {
    let w = tiny_weights();
    let base = sample_ids(17);
    let real = real_len(&base);
    // three distinct requests at three lengths (prefixes are valid inputs)
    let items = vec![
        BlockRun { nonce: 101, ids: base[..real.min(5)].to_vec() },
        BlockRun { nonce: 102, ids: base[..real].to_vec() },
        BlockRun { nonce: 103, ids: sample_ids(23) },
    ];

    // solo: each request through its own batch of one (one shared fresh
    // session — aligned truncation makes results position-independent)
    let mut s_solo = fresh_session(&w);
    let solo: Vec<_> = items
        .iter()
        .map(|it| s_solo.infer_batch(&[it.clone()]).expect("infer").pop().unwrap())
        .collect();

    // fused: all three in ONE pipeline run
    let mut s_fused = fresh_session(&w);
    let fused = s_fused.infer_batch(&items).expect("fused infer");
    assert_eq!(fused.len(), 3);
    assert_eq!(s_fused.runs(), 1, "a fused batch is one pipeline run");
    assert_eq!(s_fused.requests(), 3);

    for (i, (f, s)) in fused.iter().zip(&solo).enumerate() {
        assert_eq!(f.batch_size, 3);
        assert_eq!(
            f.logits, s.logits,
            "request {i}: fused logits must equal the solo run's"
        );
        assert_eq!(f.layer_stats.len(), s.layer_stats.len());
        for (x, y) in f.layer_stats.iter().zip(&s.layer_stats) {
            assert_eq!(x.n_in, y.n_in, "request {i} n_in");
            assert_eq!(x.n_kept, y.n_kept, "request {i} n_kept");
            assert_eq!(x.n_high, y.n_high, "request {i} n_high");
        }
    }
}

/// Serving the same request twice through one session gives identical
/// logits: with aligned truncation there is no ±1-LSB drift across the
/// session's randomness-stream positions.
#[test]
fn repeat_requests_are_deterministic_within_a_session() {
    let w = tiny_weights();
    let ids = sample_ids(17);
    let mut s = fresh_session(&w);
    let a = s.infer_batch(&[BlockRun { nonce: 9, ids: ids.clone() }]).expect("infer").pop().unwrap();
    let b = s.infer_batch(&[BlockRun { nonce: 9, ids }]).expect("infer").pop().unwrap();
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.total_stats().bytes, b.total_stats().bytes);
}

/// End-to-end through the router: a router that fuses a full bucket returns
/// exactly what a request-at-a-time router returns, while executing one
/// pipeline run instead of N.
#[test]
fn router_fused_equals_router_solo() {
    let w = tiny_weights();
    let cfg = ModelConfig::tiny();
    let wl = Workload::qnli_like(&cfg, 8);
    let mk_reqs = || -> Vec<InferenceRequest> {
        wl.batch(3, 99)
            .into_iter()
            .enumerate()
            .map(|(i, s)| InferenceRequest::new(i as u64, s.ids, EngineKind::CipherPrune))
            .collect()
    };
    let mk_router = |max_batch: usize| -> Router {
        Router::new(
            w.clone(),
            RouterConfig {
                policy: BatchPolicy {
                    max_batch,
                    linger: std::time::Duration::from_secs(100),
                    min_bucket: 8,
                    max_tokens: 64,
                },
                workers: 1, // one slot per kind → same session seed both ways
                he_n: 128,
                schedule: None,
                threads: None,
                transport: TransportSpec::Mem,
                ..Default::default()
            },
        )
    };

    // solo router: max_batch 1 releases each request as its own run
    let mut solo = mk_router(1);
    let solo_resp = solo.process(mk_reqs());
    assert_eq!(solo_resp.len(), 3);
    assert_eq!(solo.metrics.get("cipherprune").unwrap().runs, 3);

    // fused router: all three queued, then one full-bucket fused run
    let mut fused = mk_router(3);
    for r in mk_reqs() {
        fused.submit(r).unwrap();
    }
    let fused_resp = fused.step();
    assert_eq!(fused_resp.len(), 3);
    let m = fused.metrics.get("cipherprune").unwrap();
    assert_eq!(m.runs, 1, "full bucket fused into one pipeline run");
    assert_eq!(m.requests, 3);

    for (s, f) in solo_resp.iter().zip(&fused_resp) {
        assert_eq!(s.id, f.id);
        let (sr, fr) = (s.result.as_ref().unwrap(), f.result.as_ref().unwrap());
        assert_eq!(
            sr.logits, fr.logits,
            "request {}: fused serving changed the logits",
            s.id
        );
        for (x, y) in sr.layer_stats.iter().zip(&fr.layer_stats) {
            assert_eq!(x.n_kept, y.n_kept);
            assert_eq!(x.n_high, y.n_high);
        }
        assert_eq!(fr.batch_size, 3);
    }
}

/// The plaintext oracle session follows the same masked semantics: padded
/// and real-length runs agree.
#[test]
fn plaintext_session_is_mask_aware() {
    let w = tiny_weights();
    let ids = sample_ids(17);
    let real = real_len(&ids);
    let model = Arc::new(PreparedModel::prepare(w.clone()));
    let mut s = Session::start(model, EngineConfig::for_tests(EngineKind::Plaintext))
        .expect("session start");
    let a = s.infer(&ids).expect("infer");
    let b = s.infer(&ids[..real]).expect("infer");
    assert_eq!(a.logits, b.logits);
    assert_eq!(a.layer_stats[0].n_in, real);
}
