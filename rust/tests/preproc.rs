//! Offline/online phase split, end to end: preprocessed sessions must be
//! bit-identical to on-demand sessions (logits + prune/reduce decisions),
//! transport-invariant, exhaustion-safe (transparent inline fallback), and
//! exactly accounted (fill == demand; drain-based refill restores levels).

use std::sync::Arc;

use cipherprune::coordinator::{
    BlockRun, EngineConfig, EngineKind, PreparedModel, PreprocDemand, Session,
};
use cipherprune::net::TransportSpec;
use cipherprune::nn::{ModelConfig, ModelWeights, Workload};

fn setup() -> (Arc<PreparedModel>, Vec<BlockRun>) {
    let cfg = ModelConfig::tiny();
    let w = Arc::new(ModelWeights::salient(&cfg, 42));
    let model = Arc::new(PreparedModel::prepare(w));
    let items: Vec<BlockRun> = Workload::qnli_like(&cfg, 12)
        .batch(2, 7)
        .into_iter()
        .enumerate()
        .map(|(i, s)| BlockRun { nonce: 1 + i as u64, ids: s.ids })
        .collect();
    (model, items)
}

fn ec(transport: TransportSpec) -> EngineConfig {
    EngineConfig::new(EngineKind::CipherPrune).he_n(128).transport(transport)
}

/// The headline property: a session whose pools were filled by the
/// schedule-sized dry run serves the same batch bit-identically to a
/// session generating everything on demand — and the dry run is a sound
/// upper bound, so nothing falls back inline.
#[test]
fn preprocessed_matches_ondemand_bit_identically() {
    let (model, items) = setup();
    let mut od = Session::start(model.clone(), ec(TransportSpec::Mem)).expect("od session");
    let r_od = od.infer_batch(&items).expect("on-demand infer");

    let mut pp = Session::start(model.clone(), ec(TransportSpec::Mem)).expect("pp session");
    let lens: Vec<usize> = items.iter().map(|b| b.ids.len()).collect();
    let demand = pp.preprocess(&lens).expect("preprocess");
    assert!(!demand.is_empty(), "dry run must demand material");
    let r_pp = pp.infer_batch(&items).expect("preprocessed infer");

    assert_eq!(r_od.len(), r_pp.len());
    for (a, b) in r_od.iter().zip(&r_pp) {
        assert_eq!(a.logits, b.logits, "logits must be bit-identical");
        for (x, y) in a.layer_stats.iter().zip(&b.layer_stats) {
            assert_eq!(x.n_kept, y.n_kept, "prune decisions must match");
            assert_eq!(x.n_high, y.n_high, "reduce decisions must match");
        }
    }
    // soundness of the dry-run sizing: the pools covered the whole run
    let [p0, p1] = pp.preproc_reports();
    for r in [p0, p1] {
        assert_eq!(r.triples.inline, 0, "triple pool must cover the run");
        assert_eq!(r.rot_send.inline, 0, "ROT send pool must cover the run");
        assert_eq!(r.rot_recv.inline, 0, "ROT recv pool must cover the run");
        assert!(r.triples.drained > 0, "the run must actually drain the pools");
        assert!(r.rot_send.drained > 0);
    }
    assert!(pp.offline_wall_s() > 0.0);
}

/// Preprocessed sessions are transport-invariant like everything else:
/// identical logits, decisions, and per-endpoint wire digests on mem and
/// real loopback TCP (the pooled drain path has its own wire format — the
/// flips messages — so this pins it over real sockets).
#[test]
fn preprocessed_runs_are_transport_invariant() {
    let (model, items) = setup();
    let lens: Vec<usize> = items.iter().map(|b| b.ids.len()).collect();
    let run = |transport: TransportSpec| {
        let mut s = Session::start(model.clone(), ec(transport)).expect("session");
        s.preprocess(&lens).expect("preprocess");
        let rs = s.infer_batch(&items).expect("infer");
        let logits: Vec<Vec<f64>> = rs.iter().map(|r| r.logits.clone()).collect();
        let kept: Vec<Vec<usize>> = rs
            .iter()
            .map(|r| r.layer_stats.iter().map(|l| l.n_kept).collect())
            .collect();
        (logits, kept, s.transcript_digest())
    };
    let mem = run(TransportSpec::Mem);
    let tcp = run(TransportSpec::TcpLoopback);
    assert_eq!(mem.0, tcp.0, "logits must not depend on the transport");
    assert_eq!(mem.1, tcp.1, "decisions must not depend on the transport");
    assert_eq!(mem.2, tcp.2, "wire content must not depend on the transport");
}

/// Pool exhaustion mid-batch: an undersized explicit demand serves the
/// early gate calls from the pools, runs dry, and falls back to on-demand
/// generation without error — and still bit-identical to the on-demand run.
#[test]
fn pool_exhaustion_falls_back_on_demand_without_error() {
    let (model, items) = setup();
    let one = vec![items[0].clone()];
    let mut od = Session::start(model.clone(), ec(TransportSpec::Mem)).expect("od session");
    let want = od.infer_batch(&one).expect("on-demand infer");

    let mut pp = Session::start(model.clone(), ec(TransportSpec::Mem)).expect("pp session");
    let small = PreprocDemand {
        triples: 2_000,
        rot_p0s: 9_000,
        rot_p1s: 3_000,
        pad_words: 0,
    };
    pp.preprocess_with(&small).expect("small preprocess");
    let got = pp.infer_batch(&one).expect("exhausting infer");
    assert_eq!(want[0].logits, got[0].logits, "fallback must stay bit-identical");

    let [p0, _p1] = pp.preproc_reports();
    assert!(p0.triples.drained > 0, "small pool served early batches");
    assert!(p0.triples.inline > 0, "then ran dry and fell back inline");
    assert!(p0.rot_send.drained > 0);
    assert!(p0.rot_send.inline > 0);
}

/// Exact pool accounting: the fill equals the demand it was asked for
/// (per party, per direction), and the drain-based refill restores every
/// pool to its preprocessed level exactly.
#[test]
fn fill_accounting_matches_demand_and_refill_restores_levels() {
    let (model, items) = setup();
    let mut s = Session::start(model.clone(), ec(TransportSpec::Mem)).expect("session");
    let lens: Vec<usize> = items.iter().map(|b| b.ids.len()).collect();
    let d = s.preproc_demand(&lens);
    assert!(!d.is_empty());
    s.preprocess_with(&d).expect("preprocess");
    {
        let [p0, p1] = s.preproc_reports();
        assert_eq!(p0.triples.filled, d.triples, "fill == demand (triples)");
        assert_eq!(p0.rot_send.filled, d.rot_p0s, "P0 sends the P0-sender direction");
        assert_eq!(p0.rot_recv.filled, d.rot_p1s);
        assert_eq!(p1.rot_send.filled, d.rot_p1s, "P1 mirrors the directions");
        assert_eq!(p1.rot_recv.filled, d.rot_p0s);
        assert_eq!(p0.triples_avail, d.triples, "nothing drained yet");
        assert_eq!(p1.triples.filled, d.triples);
    }
    s.infer_batch(&items).expect("infer");
    let drained = (
        s.preproc_reports()[0].triples.drained,
        s.preproc_reports()[0].rot_send.drained,
        s.preproc_reports()[0].rot_recv.drained,
    );
    assert!(drained.0 > 0 && drained.1 > 0 && drained.2 > 0);
    let refill = s.refill().expect("refill");
    assert_eq!(refill.triples, drained.0, "refill regenerates the exact drain");
    assert_eq!(refill.rot_p0s, drained.1);
    assert_eq!(refill.rot_p1s, drained.2);
    let [p0, _p1] = s.preproc_reports();
    assert_eq!(p0.triples_avail, d.triples, "refill restores the triple pool");
    assert_eq!(p0.rot_send_avail, d.rot_p0s, "…and both ROT pools");
    assert_eq!(p0.rot_recv_avail, d.rot_p1s);
    // double-entry identity: everything banked is either held or drained
    assert_eq!(p0.triples.filled, p0.triples_avail + p0.triples.drained);
    assert_eq!(p0.rot_send.filled, p0.rot_send_avail + p0.rot_send.drained);
    // a second refill with nothing drained in between is a no-op
    let noop = s.refill().expect("noop refill");
    assert!(noop.is_empty());
}

/// The nonce-keyed truncation pads cannot be made before a request exists,
/// but a repeat of the same batch shape pre-expands them in bulk from the
/// learned pad plan: the replayed batch is bit-identical and P1 serves its
/// pads from the pool.
#[test]
fn pad_plan_warms_repeated_shapes() {
    let (model, items) = setup();
    let mut s = Session::start(model.clone(), ec(TransportSpec::Mem)).expect("session");
    let r1 = s.infer_batch(&items).expect("first batch");
    // exact replay: same (nonce, content) pairs reconstruct identically
    let r2 = s.infer_batch(&items).expect("replayed batch");
    for (a, b) in r1.iter().zip(&r2) {
        assert_eq!(a.logits, b.logits, "replay must be bit-identical");
    }
    let [_p0, p1] = s.preproc_reports();
    assert!(p1.pads.filled > 0, "the plan pre-expanded the second run's pads");
    assert_eq!(
        p1.pads.drained, p1.pads.filled,
        "an identical replay consumes the pre-expansion exactly"
    );
    assert_eq!(
        p1.pads.inline, p1.pads.drained,
        "first run inline == second run pooled (same truncation trace)"
    );
}

/// `EngineConfig::preprocess_for` wires the offline phase into session
/// start: the first request is online-only and bit-identical to a plain
/// session's.
#[test]
fn preprocess_at_session_start() {
    let (model, items) = setup();
    let one = vec![items[0].clone()];
    let mut plain = Session::start(model.clone(), ec(TransportSpec::Mem)).expect("plain");
    let want = plain.infer_batch(&one).expect("infer");

    let cfg = ec(TransportSpec::Mem).preprocess_for(&[one[0].ids.len()]);
    let mut warm = Session::start(model.clone(), cfg).expect("warm session");
    assert!(warm.offline_wall_s() > 0.0, "start ran the offline phase");
    assert!(warm.preproc_reports()[0].preprocessed());
    let got = warm.infer_batch(&one).expect("online-only infer");
    assert_eq!(want[0].logits, got[0].logits);
    let [p0, _] = warm.preproc_reports();
    assert_eq!(p0.triples.inline, 0, "the first request was online-only");
    assert_eq!(p0.rot_send.inline, 0);
}
