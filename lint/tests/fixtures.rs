//! Self-tests over the fixture corpus: every rule family has at least one
//! must-fire and one must-pass snippet, the allow-marker path is exercised
//! both with and without a reason, scoping is honored, and the real tree
//! stays clean.

use std::path::Path;

use mpc_lint::{lint_source, Finding, Rule};

fn lint_fixture(rel: &str, file: &str) -> Vec<Finding> {
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("fixtures").join(file);
    let src = std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("reading fixture {:?}: {}", p, e));
    lint_source(rel, &src)
}

fn count(fs: &[Finding], rule: Rule, allowed: bool) -> usize {
    fs.iter().filter(|f| f.rule == rule && f.allowed == allowed).count()
}

fn unallowed(fs: &[Finding]) -> usize {
    fs.iter().filter(|f| !f.allowed).count()
}

#[test]
fn determinism_fires_on_clock_rng_and_hash() {
    let fs = lint_fixture("protocols/fixture.rs", "determinism_fire.rs");
    assert_eq!(count(&fs, Rule::Determinism, false), 4, "{:#?}", fs);
    let lines: Vec<usize> = fs.iter().map(|f| f.line).collect();
    assert!(lines.contains(&7), "Instant::now site: {:?}", lines);
    assert!(lines.contains(&12), "thread_rng site: {:?}", lines);
}

#[test]
fn determinism_fires_on_hash_container_in_silent_ot() {
    // the silent-OT extension sits on the transcript-affecting `ot/` scope:
    // hash-order iteration there would scramble the noisy-row correction
    // stream and break spill/dealer bit-identity
    // three HashMap tokens: the use declaration, the binding type, ::new()
    let fs = lint_fixture("ot/silent.rs", "determinism_silent_fire.rs");
    assert_eq!(count(&fs, Rule::Determinism, false), 3, "{:#?}", fs);
    assert!(
        fs.iter().any(|f| f.msg.contains("HashMap")),
        "expected a HashMap hash-order finding: {:#?}",
        fs
    );
}

#[test]
fn determinism_passes_on_btreemap() {
    let fs = lint_fixture("protocols/fixture.rs", "determinism_pass.rs");
    assert_eq!(unallowed(&fs), 0, "{:#?}", fs);
}

#[test]
fn determinism_marker_with_reason_allows() {
    let fs = lint_fixture("protocols/fixture.rs", "determinism_allow.rs");
    assert_eq!(unallowed(&fs), 0, "{:#?}", fs);
    assert_eq!(count(&fs, Rule::Determinism, true), 1, "{:#?}", fs);
}

#[test]
fn channel_fires_on_unmirrored_arms() {
    let fs = lint_fixture("protocols/fixture.rs", "channel_fire.rs");
    assert_eq!(count(&fs, Rule::Channel, false), 1, "{:#?}", fs);
    assert!(fs.iter().any(|f| f.msg.contains("do not mirror")), "{:#?}", fs);
}

#[test]
fn channel_passes_on_mirrored_and_symmetric_arms() {
    let fs = lint_fixture("protocols/fixture.rs", "channel_pass.rs");
    assert_eq!(unallowed(&fs), 0, "{:#?}", fs);
}

#[test]
fn secret_fires_on_share_branch_and_index() {
    let fs = lint_fixture("gates/fixture.rs", "secret_fire.rs");
    assert_eq!(count(&fs, Rule::Secret, false), 2, "{:#?}", fs);
    assert!(fs.iter().any(|f| f.msg.contains("condition depends")), "{:#?}", fs);
    assert!(fs.iter().any(|f| f.msg.contains("index depends")), "{:#?}", fs);
}

#[test]
fn secret_passes_on_opened_values_and_shape_projections() {
    let fs = lint_fixture("gates/fixture.rs", "secret_pass.rs");
    assert_eq!(unallowed(&fs), 0, "{:#?}", fs);
}

#[test]
fn panic_fires_on_unwrap_and_macro() {
    let fs = lint_fixture("net/fixture.rs", "panic_fire.rs");
    assert_eq!(count(&fs, Rule::Panic, false), 2, "{:#?}", fs);
}

#[test]
fn panic_passes_on_typed_errors() {
    let fs = lint_fixture("net/fixture.rs", "panic_pass.rs");
    assert_eq!(unallowed(&fs), 0, "{:#?}", fs);
}

#[test]
fn panic_rule_respects_module_scope() {
    // the same unwrap-heavy code is fine outside net/ + serving/
    let fs = lint_fixture("protocols/fixture.rs", "panic_fire.rs");
    assert_eq!(unallowed(&fs), 0, "{:#?}", fs);
}

#[test]
fn unsafe_fires_on_block_and_fn_everywhere() {
    // the unsafe rule is not scoped to a module family: it applies to every
    // rel outside the allow list, including modules no other rule covers
    for rel in ["util/fixture.rs", "he/ntt.rs", "net/fixture.rs"] {
        let fs = lint_fixture(rel, "unsafe_fire.rs");
        assert_eq!(count(&fs, Rule::Unsafe, false), 2, "rel={}: {:#?}", rel, fs);
    }
}

#[test]
fn unsafe_passes_in_allow_listed_simd_modules() {
    for rel in ["he/simd.rs", "ot/simd.rs"] {
        let fs = lint_fixture(rel, "unsafe_pass.rs");
        assert_eq!(unallowed(&fs), 0, "rel={}: {:#?}", rel, fs);
        let fs = lint_fixture(rel, "unsafe_fire.rs");
        assert_eq!(unallowed(&fs), 0, "rel={}: {:#?}", rel, fs);
    }
    // the same opt-out fixture outside the allow list fires on its two
    // `unsafe` tokens (the `#![allow(unsafe_code)]` attribute itself does
    // not fire: `unsafe_code` lexes as one distinct ident)
    let fs = lint_fixture("util/fixture.rs", "unsafe_pass.rs");
    assert_eq!(count(&fs, Rule::Unsafe, false), 2, "{:#?}", fs);
}

#[test]
fn cfg_test_regions_are_skipped() {
    let fs = lint_fixture("net/fixture.rs", "test_region_pass.rs");
    assert_eq!(unallowed(&fs), 0, "{:#?}", fs);
}

#[test]
fn marker_without_reason_is_a_finding() {
    let fs = lint_fixture("net/fixture.rs", "marker_bad.rs");
    assert_eq!(count(&fs, Rule::Marker, false), 1, "{:#?}", fs);
}

#[test]
fn json_report_is_well_formed() {
    let fs = lint_fixture("net/fixture.rs", "panic_fire.rs");
    let j = mpc_lint::report::to_json(&fs);
    assert!(j.contains("\"unallowed\": 2"), "{}", j);
    assert!(j.contains("\"rule\": \"panic\""), "{}", j);
    assert!(j.trim_end().ends_with('}'), "{}", j);
}

/// The gate itself: the real tree must carry zero unallowed findings, so
/// tier-1 `cargo test` enforces the invariants, not just the CI lint job.
#[test]
fn real_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("rust").join("src");
    let fs = mpc_lint::lint_tree(&root).expect("lint rust/src");
    let bad: Vec<String> = fs.iter().filter(|f| !f.allowed).map(|f| f.render()).collect();
    assert!(bad.is_empty(), "unallowed findings in rust/src:\n{}", bad.join("\n"));
}
