//! A minimal Rust lexer: just enough token structure for the four rule
//! families (identifiers, punctuation, literals, lifetimes), with comments
//! collected per-line so allow-markers can be matched to findings.
//!
//! Deliberately NOT a full parser: the rules only need token order and
//! matched delimiters, and a hand-rolled lexer keeps the crate free of
//! external dependencies (see lint/Cargo.toml).

use std::collections::BTreeMap;

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum TokKind {
    Ident,
    Punct,
    Lit,
    Lifetime,
}

#[derive(Clone, Debug)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: usize,
}

pub struct Lexed {
    pub toks: Vec<Tok>,
    /// line number → comments that START on that line (line and block).
    pub comments: BTreeMap<usize, Vec<String>>,
}

fn span(cs: &[char], a: usize, b: usize) -> String {
    cs[a..b.min(cs.len())].iter().collect()
}

/// `r"…"` / `r#"…"#` / `br#"…"#` opener at `i`: returns (body start, hashes).
fn raw_string_open(cs: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if cs.get(j) == Some(&'b') {
        j += 1;
    }
    if cs.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while cs.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if cs.get(j) == Some(&'"') {
        Some((j + 1, hashes))
    } else {
        None
    }
}

fn hashes_at(cs: &[char], mut j: usize) -> usize {
    let mut n = 0;
    while cs.get(j) == Some(&'#') {
        n += 1;
        j += 1;
    }
    n
}

pub fn lex(src: &str) -> Lexed {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut comments: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment
        if c == '/' && cs.get(i + 1) == Some(&'/') {
            let mut j = i;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            comments.entry(line).or_default().push(span(&cs, i, j));
            i = j;
            continue;
        }
        // block comment (nested)
        if c == '/' && cs.get(i + 1) == Some(&'*') {
            let ln0 = line;
            let mut depth = 1usize;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if cs[j] == '/' && cs.get(j + 1) == Some(&'*') {
                    depth += 1;
                    j += 2;
                } else if cs[j] == '*' && cs.get(j + 1) == Some(&'/') {
                    depth -= 1;
                    j += 2;
                } else {
                    if cs[j] == '\n' {
                        line += 1;
                    }
                    j += 1;
                }
            }
            comments.entry(ln0).or_default().push(span(&cs, i, j));
            i = j;
            continue;
        }
        // raw string literal
        if let Some((body, hashes)) = raw_string_open(&cs, i) {
            let mut j = body;
            while j < n {
                if cs[j] == '"' && hashes_at(&cs, j + 1) >= hashes {
                    j = j + 1 + hashes;
                    break;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Lit, text: span(&cs, i, j), line });
            i = j;
            continue;
        }
        // string literal (and byte string)
        if c == '"' || (c == 'b' && cs.get(i + 1) == Some(&'"')) {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            while j < n {
                if cs[j] == '\\' {
                    j += 2;
                    continue;
                }
                if cs[j] == '"' {
                    j += 1;
                    break;
                }
                if cs[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Lit, text: span(&cs, i, j), line });
            i = j;
            continue;
        }
        // char literal vs lifetime
        if c == '\'' {
            if i + 1 < n && (cs[i + 1].is_alphabetic() || cs[i + 1] == '_') {
                let mut j = i + 1;
                while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                if j == i + 2 && cs.get(j) == Some(&'\'') {
                    toks.push(Tok { kind: TokKind::Lit, text: span(&cs, i, j + 1), line });
                    i = j + 1;
                } else {
                    toks.push(Tok { kind: TokKind::Lifetime, text: span(&cs, i, j), line });
                    i = j;
                }
                continue;
            }
            let mut j = i + 1;
            if cs.get(j) == Some(&'\\') {
                j += 2;
            } else {
                j += 1;
            }
            while j < n && cs[j] != '\'' {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Lit, text: span(&cs, i, j + 1), line });
            i = j + 1;
            continue;
        }
        // identifier / keyword
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            toks.push(Tok { kind: TokKind::Ident, text: span(&cs, i, j), line });
            i = j;
            continue;
        }
        // numeric literal (`.` continues only into a fraction, so `0..n`
        // and `8.div_ceil(x)` stay separate tokens)
        if c.is_ascii_digit() {
            let mut j = i;
            let mut seen_dot = false;
            while j < n {
                let ch = cs[j];
                if ch.is_ascii_alphanumeric() || ch == '_' {
                    j += 1;
                    continue;
                }
                if ch == '.'
                    && !seen_dot
                    && j + 1 < n
                    && cs[j + 1].is_ascii_digit()
                {
                    seen_dot = true;
                    j += 1;
                    continue;
                }
                break;
            }
            toks.push(Tok { kind: TokKind::Lit, text: span(&cs, i, j), line });
            i = j;
            continue;
        }
        toks.push(Tok { kind: TokKind::Punct, text: c.to_string(), line });
        i += 1;
    }
    Lexed { toks, comments }
}

/// For each opening delimiter token index, the index of its matching close.
pub fn match_spans(toks: &[Tok]) -> Vec<Option<usize>> {
    let mut m = vec![None; toks.len()];
    let mut stack: Vec<usize> = Vec::new();
    for (k, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Punct {
            match t.text.as_str() {
                "(" | "[" | "{" => stack.push(k),
                ")" | "]" | "}" => {
                    if let Some(o) = stack.pop() {
                        m[o] = Some(k);
                    }
                }
                _ => {}
            }
        }
    }
    m
}

/// Token spans of `#[cfg(test)]` items and `#[test]` functions: rules skip
/// these (tests may unwrap, time, and branch freely).
pub fn test_regions(toks: &[Tok], matches: &[Option<usize>]) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut k = 0usize;
    while k < toks.len() {
        let t = &toks[k];
        if t.kind == TokKind::Punct
            && t.text == "#"
            && k + 1 < toks.len()
            && toks[k + 1].text == "["
        {
            if let Some(close) = matches[k + 1] {
                let inner: Vec<&str> = toks[k + 2..close]
                    .iter()
                    .filter(|x| x.kind == TokKind::Ident)
                    .map(|x| x.text.as_str())
                    .collect();
                let is_test = inner.contains(&"test")
                    && (inner.first() == Some(&"cfg") || inner == ["test"]);
                if is_test {
                    // skip to the end of the next item: the body `{…}`, or
                    // a `;` for a body-less item
                    let mut j = close + 1;
                    while j < toks.len() {
                        let x = &toks[j];
                        if x.kind == TokKind::Punct && x.text == ";" {
                            break;
                        }
                        if x.kind == TokKind::Punct && (x.text == "(" || x.text == "[") {
                            j = matches[j].unwrap_or(j) + 1;
                            continue;
                        }
                        if x.kind == TokKind::Punct && x.text == "{" {
                            regions.push((k, matches[j].unwrap_or(toks.len() - 1)));
                            break;
                        }
                        j += 1;
                    }
                    k = j;
                }
            }
        }
        k += 1;
    }
    regions
}

pub fn in_regions(k: usize, regions: &[(usize, usize)]) -> bool {
    regions.iter().any(|&(a, b)| a <= k && k <= b)
}
