//! Findings and the machine-readable report (hand-rolled JSON — the crate
//! is dependency-free by policy, see Cargo.toml).

use crate::rules::Rule;

#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: Rule,
    /// path relative to the linted root, `/`-separated.
    pub path: String,
    pub line: usize,
    pub msg: String,
    /// true when an allow-marker with a reason covers this finding.
    pub allowed: bool,
}

impl Finding {
    pub fn render(&self) -> String {
        format!(
            "{} [{}] {}:{}: {}",
            if self.allowed { "allowed" } else { "FINDING" },
            self.rule.as_str(),
            self.path,
            self.line,
            self.msg
        )
    }
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// The full report as a JSON document:
/// `{"unallowed": N, "allowed": M, "findings": [{rule, path, line, msg, allowed}…]}`.
pub fn to_json(findings: &[Finding]) -> String {
    let unallowed = findings.iter().filter(|f| !f.allowed).count();
    let allowed = findings.len() - unallowed;
    let mut out = String::new();
    out.push_str(&format!(
        "{{\n  \"unallowed\": {},\n  \"allowed\": {},\n  \"findings\": [",
        unallowed, allowed
    ));
    for (i, f) in findings.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"rule\": \"{}\", \"path\": \"{}\", \"line\": {}, \
             \"msg\": \"{}\", \"allowed\": {}}}",
            f.rule.as_str(),
            json_escape(&f.path),
            f.line,
            json_escape(&f.msg),
            f.allowed
        ));
    }
    out.push_str("\n  ]\n}\n");
    out
}
