//! Allow-marker parsing: `// mpc-lint: allow(<rule>) reason="..."`.
//!
//! A marker suppresses findings of `<rule>` on its own line, or — when the
//! marker sits in a comment block — on the first code line directly below
//! that block. The `reason` is mandatory: a marker without one is itself
//! reported (rule `marker`), so every suppression in the tree carries a
//! written justification.

use std::collections::{BTreeMap, BTreeSet};

pub struct Markers {
    /// line → rules allowed on that line.
    pub allow: BTreeMap<usize, BTreeSet<String>>,
    /// markers missing their `reason="…"` (line, rule).
    pub bad: Vec<(usize, String)>,
}

/// Every `mpc-lint: allow(rule) [reason="…"]` occurrence in one comment.
fn parse_comment(s: &str) -> Vec<(String, Option<String>)> {
    let mut out = Vec::new();
    let mut rest = s;
    while let Some(p) = rest.find("mpc-lint:") {
        rest = &rest[p + "mpc-lint:".len()..];
        let t = rest.trim_start();
        let Some(t) = t.strip_prefix("allow(") else {
            continue;
        };
        let Some(cp) = t.find(')') else {
            continue;
        };
        let rule = t[..cp].trim().to_string();
        let after = t[cp + 1..].trim_start();
        let reason = after
            .strip_prefix("reason=\"")
            .and_then(|r| r.find('"').map(|q| r[..q].to_string()))
            .filter(|r| !r.trim().is_empty());
        out.push((rule, reason));
        rest = &t[cp + 1..];
    }
    out
}

pub fn collect(comments: &BTreeMap<usize, Vec<String>>) -> Markers {
    let mut allow: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();
    let mut bad = Vec::new();
    for (&line, texts) in comments {
        for text in texts {
            for (rule, reason) in parse_comment(text) {
                if reason.is_some() {
                    allow.entry(line).or_default().insert(rule);
                } else {
                    bad.push((line, rule));
                }
            }
        }
    }
    Markers { allow, bad }
}

impl Markers {
    /// Is `rule` allowed at `line` — by a marker on the same line, or by one
    /// in the run of comment lines directly above it?
    pub fn allowed(
        &self,
        rule: &str,
        line: usize,
        comments: &BTreeMap<usize, Vec<String>>,
    ) -> bool {
        if self.allow.get(&line).is_some_and(|r| r.contains(rule)) {
            return true;
        }
        let mut ln = line.saturating_sub(1);
        while ln > 0 && (comments.contains_key(&ln) || self.allow.contains_key(&ln)) {
            if self.allow.get(&ln).is_some_and(|r| r.contains(rule)) {
                return true;
            }
            ln -= 1;
        }
        false
    }
}
