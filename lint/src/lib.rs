//! `mpc-lint` — repo-specific static analysis for the CipherPrune tree.
//!
//! Five rule families, each guarding an invariant the protocol stack sells
//! (see README "Machine-checked invariants"):
//!
//! - **determinism**: no wall clocks, ambient RNG, or hash-order iteration
//!   in transcript-affecting modules (`protocols/`, `gates/`, `ot/`, `he/`
//!   — including the silent-OT extension `ot/silent.rs` — plus
//!   `coordinator/pipeline.rs` and the trusted-dealer streams in
//!   `coordinator/dealer.rs`; hash-order also `coordinator/router.rs`) —
//!   logits and wire digests must be bit-identical run to run.
//! - **channel**: role-branched `if is_p0() { … } else { … }` blocks must
//!   mirror their send/recv sequences — the coalescing-liveness argument,
//!   machine-checked instead of hand-traced.
//! - **secret**: `if`/`while`/`match`/`assert!` conditions and index
//!   expressions in `protocols/`+`gates/` must not depend on share-typed
//!   values unless they flowed through `open`/`open_bits` — 2PC control
//!   flow and memory access must be input-independent.
//! - **panic**: no `unwrap()`/`expect()`/panicking macros in `net/` and
//!   `serving/` — a malformed frame disconnects one client, it never kills
//!   a server thread.
//! - **unsafe**: `unsafe` appears nowhere outside the two allow-listed SIMD
//!   kernel modules (`he/simd.rs`, `ot/simd.rs`), which carry the crate's
//!   only scoped `#![allow(unsafe_code)]` and a documented safety contract.
//!
//! Suppressions are explicit and justified:
//! `// mpc-lint: allow(<rule>) reason="..."` on the finding's line or in
//! the comment block directly above it. A marker without a reason is
//! itself a finding (rule `marker`).

pub mod lexer;
pub mod marker;
pub mod report;
pub mod rules;

use std::io;
use std::path::{Path, PathBuf};

pub use report::Finding;
pub use rules::Rule;

const TRANSCRIPT_SCOPE: &[&str] = &["protocols/", "gates/", "ot/", "he/"];
const CHANNEL_SCOPE: &[&str] = &["protocols/", "gates/", "ot/", "he/", "party/", "coordinator/"];
const SECRET_SCOPE: &[&str] = &["protocols/", "gates/"];
const PANIC_SCOPE: &[&str] = &["net/", "serving/"];

/// The only files allowed to contain `unsafe`: the reviewed AVX2 kernel
/// modules, which opt in via a scoped `#![allow(unsafe_code)]` against the
/// crate-level `unsafe_code = "deny"` and document their safety contract.
const UNSAFE_ALLOWED: &[&str] = &["he/simd.rs", "ot/simd.rs"];

fn in_scope(rel: &str, prefixes: &[&str]) -> bool {
    prefixes.iter().any(|p| rel.starts_with(p))
}

/// Lint one file's source. `rel` is its path relative to the linted root
/// (`/`-separated) — it selects which rules apply.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let lexed = lexer::lex(src);
    let matches = lexer::match_spans(&lexed.toks);
    let tregions = lexer::test_regions(&lexed.toks, &matches);
    let markers = marker::collect(&lexed.comments);

    let mut raw: Vec<rules::RawFinding> = Vec::new();
    if in_scope(rel, TRANSCRIPT_SCOPE)
        || rel == "coordinator/pipeline.rs"
        || rel == "coordinator/dealer.rs"
    {
        rules::determinism_time_rng(&lexed.toks, &tregions, &mut raw);
        rules::determinism_hash_iter(&lexed.toks, &tregions, &mut raw);
    } else if rel == "coordinator/router.rs" {
        rules::determinism_hash_iter(&lexed.toks, &tregions, &mut raw);
    }
    if in_scope(rel, CHANNEL_SCOPE) {
        rules::channel_discipline(&lexed.toks, &matches, &tregions, &mut raw);
    }
    if in_scope(rel, SECRET_SCOPE) {
        rules::secret_independence(&lexed.toks, &matches, &tregions, &mut raw);
    }
    if in_scope(rel, PANIC_SCOPE) {
        rules::panic_hygiene(&lexed.toks, &tregions, &mut raw);
    }
    if !UNSAFE_ALLOWED.contains(&rel) {
        rules::unsafe_confinement(&lexed.toks, &mut raw);
    }

    let mut findings: Vec<Finding> = Vec::new();
    for (line, rule) in &markers.bad {
        findings.push(Finding {
            rule: Rule::Marker,
            path: rel.to_string(),
            line: *line,
            msg: format!("allow({}) without a reason=\"...\"", rule),
            allowed: false,
        });
    }
    for f in raw {
        let allowed = markers.allowed(f.rule.as_str(), f.line, &lexed.comments);
        findings.push(Finding {
            rule: f.rule,
            path: rel.to_string(),
            line: f.line,
            msg: f.msg,
            allowed,
        });
    }
    // one finding per (rule, line): a line with two `HashMap` tokens is one
    // problem, not two
    findings.sort_by(|a, b| {
        (a.line, a.rule.as_str(), &a.msg).cmp(&(b.line, b.rule.as_str(), &b.msg))
    });
    findings.dedup_by(|a, b| a.rule == b.rule && a.line == b.line && a.path == b.path);
    findings
}

fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(dir)?.collect::<Result<Vec<_>, io::Error>>()?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let p = e.path();
        if p.is_dir() {
            walk(&p, out)?;
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (deterministic order), returning all
/// findings with paths relative to `root`.
pub fn lint_tree(root: &Path) -> io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    walk(root, &mut files)?;
    let mut findings = Vec::new();
    for f in &files {
        let rel: String = f
            .strip_prefix(root)
            .unwrap_or(f)
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(f)?;
        findings.extend(lint_source(&rel, &src));
    }
    Ok(findings)
}
