//! The five rule families. Each rule walks the token stream of one file
//! (with its delimiter matches and test-region spans) and pushes findings;
//! allow-marker filtering happens in the driver (`lib.rs`), so rules report
//! every hit.
//!
//! The rules are token-structural on purpose: every invariant they encode
//! (wire determinism, send⇔recv mirroring, secret-independent control flow,
//! panic-free connection paths, unsafe confinement) is visible at
//! token/brace level, which keeps the checker dependency-free and trivially
//! auditable.

use crate::lexer::{in_regions, Tok, TokKind};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Rule {
    Determinism,
    Channel,
    Secret,
    Panic,
    Unsafe,
    Marker,
}

impl Rule {
    pub fn as_str(&self) -> &'static str {
        match self {
            Rule::Determinism => "determinism",
            Rule::Channel => "channel",
            Rule::Secret => "secret",
            Rule::Panic => "panic",
            Rule::Unsafe => "unsafe",
            Rule::Marker => "marker",
        }
    }
}

#[derive(Clone, Debug)]
pub struct RawFinding {
    pub rule: Rule,
    pub line: usize,
    pub msg: String,
}

fn is_punct(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Punct && t.text == s
}

fn is_ident(t: &Tok, s: &str) -> bool {
    t.kind == TokKind::Ident && t.text == s
}

// ------------------------------------------------------------ determinism

/// Ambient RNG entry points; the repo's seeded `Xoshiro256`/`AesPrg` are the
/// sanctioned sources.
const AMBIENT_RNG: &[&str] = &["thread_rng", "OsRng", "from_entropy", "getrandom"];

/// Wall-clock reads whose values could leak into the transcript.
pub fn determinism_time_rng(
    toks: &[Tok],
    tregions: &[(usize, usize)],
    out: &mut Vec<RawFinding>,
) {
    for (k, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || in_regions(k, tregions) {
            continue;
        }
        if t.text == "Instant"
            && toks.get(k + 1).is_some_and(|x| is_punct(x, ":"))
            && toks.get(k + 2).is_some_and(|x| is_punct(x, ":"))
            && toks.get(k + 3).is_some_and(|x| is_ident(x, "now"))
        {
            out.push(RawFinding {
                rule: Rule::Determinism,
                line: t.line,
                msg: "Instant::now in a transcript-affecting module".to_string(),
            });
        } else if t.text == "SystemTime" {
            out.push(RawFinding {
                rule: Rule::Determinism,
                line: t.line,
                msg: "SystemTime in a transcript-affecting module".to_string(),
            });
        } else if AMBIENT_RNG.contains(&t.text.as_str()) {
            out.push(RawFinding {
                rule: Rule::Determinism,
                line: t.line,
                msg: format!("ambient RNG `{}`", t.text),
            });
        }
    }
}

/// `HashMap`/`HashSet` anywhere in a determinism-scoped module: their
/// iteration order is seeded per-process, so any loop over one can reorder
/// scheduling, reports, or (worst case) wire traffic between runs.
pub fn determinism_hash_iter(
    toks: &[Tok],
    tregions: &[(usize, usize)],
    out: &mut Vec<RawFinding>,
) {
    for (k, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Ident
            && (t.text == "HashMap" || t.text == "HashSet")
            && !in_regions(k, tregions)
        {
            out.push(RawFinding {
                rule: Rule::Determinism,
                line: t.line,
                msg: format!(
                    "{} in a determinism-scoped module (iteration order is \
                     nondeterministic); use BTreeMap/BTreeSet or sorted keys",
                    t.text
                ),
            });
        }
    }
}

// ---------------------------------------------------------------- channel

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
enum Dir {
    Send,
    Recv,
    Exch,
}

impl Dir {
    fn mirror(self) -> Dir {
        match self {
            Dir::Send => Dir::Recv,
            Dir::Recv => Dir::Send,
            Dir::Exch => Dir::Exch,
        }
    }
}

/// Classify a called identifier as a communication op and name its payload
/// so `cot_send_wide` pairs with `cot_recv_wide` but not with `cot_recv`.
fn classify_comm(name: &str) -> Option<(Dir, String)> {
    match name {
        // raw transport ops are direction-symmetric plumbing, not protocol
        "send_frame" | "recv_frame" | "recv_frame_timeout" => None,
        "send_vec" => Some((Dir::Send, "bytes".to_string())),
        "share_input" => Some((Dir::Send, "shares".to_string())),
        "recv_shares" => Some((Dir::Recv, "shares".to_string())),
        "evaluate_and_mask" => Some((Dir::Send, "he_result".to_string())),
        "recv_and_decrypt" => Some((Dir::Recv, "he_result".to_string())),
        "exchange_u64s" => Some((Dir::Exch, "u64s".to_string())),
        _ if name.contains("send") => Some((Dir::Send, payload(name, "send"))),
        _ if name.contains("recv") => Some((Dir::Recv, payload(name, "recv"))),
        _ => None,
    }
}

fn payload(name: &str, verb: &str) -> String {
    name.replace(verb, "").trim_matches('_').replace("__", "_")
}

/// Is the `if` condition a pure role test (`…is_p0()`, bare `p0`,
/// `evaluating`)? Returns `Some(negated)`.
fn role_condition(cond: &[&Tok]) -> Option<bool> {
    let mut neg = 0usize;
    let mut ts = cond;
    while ts.first().is_some_and(|t| is_punct(t, "!")) {
        neg += 1;
        ts = &ts[1..];
    }
    if ts.is_empty() {
        return None;
    }
    let idents: Vec<&str> =
        ts.iter().filter(|t| t.kind == TokKind::Ident).map(|t| t.text.as_str()).collect();
    let only_call_chain = ts.iter().all(|t| {
        t.kind == TokKind::Ident || matches!(t.text.as_str(), "." | "(" | ")")
    });
    if idents.last() == Some(&"is_p0")
        && ts.len() >= 2
        && is_punct(ts[ts.len() - 2], "(")
        && is_punct(ts[ts.len() - 1], ")")
        && only_call_chain
    {
        return Some(neg % 2 == 1);
    }
    if ts.len() == 1
        && ts[0].kind == TokKind::Ident
        && matches!(ts[0].text.as_str(), "p0" | "evaluating" | "is_p0")
    {
        return Some(neg % 2 == 1);
    }
    None
}

/// Communication calls in `toks[a..=b]`, in order.
fn comm_seq(toks: &[Tok], a: usize, b: usize) -> Vec<(Dir, String, usize)> {
    let mut seq = Vec::new();
    let mut k = a;
    while k <= b && k < toks.len() {
        let t = &toks[k];
        if t.kind == TokKind::Ident && k + 1 <= b && is_punct(&toks[k + 1], "(") {
            if let Some((d, p)) = classify_comm(&t.text) {
                seq.push((d, p, t.line));
            }
        }
        k += 1;
    }
    seq
}

fn fmt_seq(seq: &[(Dir, String, usize)]) -> String {
    let parts: Vec<String> = seq.iter().map(|(d, p, _)| format!("{:?}:{}", d, p)).collect();
    format!("[{}]", parts.join(", "))
}

/// Role-branched comm sequences must mirror: every send in the P0 arm pairs
/// a recv of the same payload at the same position in the P1 arm (and vice
/// versa); symmetric exchanges pair with themselves. This is the coalescing
/// liveness argument — a non-mirrored pair deadlocks once frames coalesce.
pub fn channel_discipline(
    toks: &[Tok],
    matches: &[Option<usize>],
    tregions: &[(usize, usize)],
    out: &mut Vec<RawFinding>,
) {
    for (k, t) in toks.iter().enumerate() {
        if !is_ident(t, "if") || in_regions(k, tregions) {
            continue;
        }
        if toks.get(k + 1).is_some_and(|x| is_ident(x, "let")) {
            continue;
        }
        // condition tokens up to the `{` at delimiter depth 0
        let mut j = k + 1;
        let mut cond: Vec<&Tok> = Vec::new();
        while j < toks.len() {
            let x = &toks[j];
            if x.kind == TokKind::Punct && (x.text == "(" || x.text == "[") {
                let Some(end) = matches[j] else { break };
                for c in &toks[j..=end] {
                    cond.push(c);
                }
                j = end + 1;
                continue;
            }
            if is_punct(x, "{") {
                break;
            }
            cond.push(x);
            j += 1;
        }
        if j >= toks.len() {
            continue;
        }
        let Some(negated) = role_condition(&cond) else { continue };
        let then_open = j;
        let Some(then_close) = matches[then_open] else { continue };
        // else arm?
        let mut arm2: Option<(usize, usize)> = None;
        let e = then_close + 1;
        if toks.get(e).is_some_and(|x| is_ident(x, "else")) {
            if toks.get(e + 1).is_some_and(|x| is_ident(x, "if")) {
                continue; // chained role branch: out of scope, rare
            }
            if toks.get(e + 1).is_some_and(|x| is_punct(x, "{")) {
                if let Some(c2) = matches[e + 1] {
                    arm2 = Some((e + 1, c2));
                }
            }
        }
        let seq_then = comm_seq(toks, then_open + 1, then_close.saturating_sub(1));
        let seq_else = match arm2 {
            Some((o, c)) => comm_seq(toks, o + 1, c.saturating_sub(1)),
            None => Vec::new(),
        };
        if seq_then.is_empty() && seq_else.is_empty() {
            continue;
        }
        if arm2.is_none() {
            out.push(RawFinding {
                rule: Rule::Channel,
                line: t.line,
                msg: format!(
                    "role-branched send/recv without a mirroring else arm: {}",
                    fmt_seq(&seq_then)
                ),
            });
            continue;
        }
        let (p0_seq, p1_seq) = if negated {
            (&seq_else, &seq_then)
        } else {
            (&seq_then, &seq_else)
        };
        let mut ok = p0_seq.len() == p1_seq.len();
        if ok {
            for ((d0, pay0, _), (d1, pay1, _)) in p0_seq.iter().zip(p1_seq.iter()) {
                if *d1 != d0.mirror() || pay0 != pay1 {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            out.push(RawFinding {
                rule: Rule::Channel,
                line: t.line,
                msg: format!(
                    "role arms do not mirror: P0={} P1={}",
                    fmt_seq(p0_seq),
                    fmt_seq(p1_seq)
                ),
            });
        }
    }
}

// ----------------------------------------------------------------- secret

/// Gate/protocol calls whose results are secret shares.
const SHARE_SOURCES: &[&str] = &[
    "share_input",
    "recv_shares",
    "triples",
    "mul_vec",
    "square_vec",
    "and_bits",
    "not_bits",
    "xor_bits",
    "b2a",
    "mux",
    "mux_wide",
    "select",
    "trunc_vec",
    "mul_trunc_vec",
    "scale_const_trunc",
    "millionaires",
    "millionaires_bits",
    "msb",
    "msb_bits",
    "cmp_gt_const",
    "cmp_gt_consts",
    "cmp_gt",
    "is_nonneg",
    "cot_send",
    "cot_recv",
    "cot_send_wide",
    "cot_recv_wide",
    "otk_recv_flat",
    "rot_send",
    "rot_recv",
];

/// The sanctioned reveal APIs: a value that flowed through these is public.
const SANITIZERS: &[&str] = &["open", "open_bits"];

/// Structure-only projections of a share container — its shape is public
/// (lengths are public by protocol design, PR 3), only elements are secret.
const PUBLIC_PROJ: &[&str] = &["len", "is_empty", "rows", "cols", "capacity"];

/// Share-carrying types for parameter tainting.
const SHARE_TYPES: &[&str] = &["Ring", "RingMat"];

/// At `toks[k]` (an ident): does a `[…]*.proj` suffix make the use public?
/// Returns (is_public, index after the projection).
fn publicly_projected(toks: &[Tok], k: usize, b: usize) -> (bool, usize) {
    let mut j = k + 1;
    while j <= b && is_punct(&toks[j], "[") {
        let mut depth = 0i64;
        while j <= b {
            if is_punct(&toks[j], "[") {
                depth += 1;
            } else if is_punct(&toks[j], "]") {
                depth -= 1;
            }
            j += 1;
            if depth == 0 {
                break;
            }
        }
        if depth != 0 {
            return (false, j);
        }
    }
    if j + 1 <= b
        && is_punct(&toks[j], ".")
        && toks[j + 1].kind == TokKind::Ident
        && PUBLIC_PROJ.contains(&toks[j + 1].text.as_str())
    {
        return (true, j + 2);
    }
    (false, k + 1)
}

/// First use of a tainted local in `toks[a..=b]` that is not a public
/// projection (and not a field access `x.tainted`).
fn tainted_use<'a>(
    toks: &'a [Tok],
    a: usize,
    b: usize,
    tainted: &std::collections::BTreeSet<String>,
) -> Option<(usize, &'a str)> {
    let mut k = a;
    while k <= b && k < toks.len() {
        let t = &toks[k];
        if t.kind == TokKind::Ident && tainted.contains(&t.text) {
            if k > a && is_punct(&toks[k - 1], ".") {
                k += 1;
                continue;
            }
            let (public, next) = publicly_projected(toks, k, b);
            if public {
                k = next;
                continue;
            }
            return Some((t.line, &t.text));
        }
        k += 1;
    }
    None
}

/// All `fn` items: (name, param span (open..close), body span (open..close)).
fn find_fns(
    toks: &[Tok],
    matches: &[Option<usize>],
) -> Vec<(String, (usize, usize), (usize, usize))> {
    let mut fns = Vec::new();
    for (k, t) in toks.iter().enumerate() {
        if !is_ident(t, "fn") || k + 1 >= toks.len() {
            continue;
        }
        let name = toks[k + 1].text.clone();
        let mut j = k + 2;
        // generics
        if j < toks.len() && is_punct(&toks[j], "<") {
            let mut depth = 0i64;
            while j < toks.len() {
                if is_punct(&toks[j], "<") {
                    depth += 1;
                } else if is_punct(&toks[j], ">") {
                    depth -= 1;
                }
                if depth == 0 {
                    break;
                }
                j += 1;
            }
            j += 1;
        }
        if j >= toks.len() || !is_punct(&toks[j], "(") {
            continue;
        }
        let Some(pclose) = matches[j] else { continue };
        let params = (j, pclose);
        // body `{` (skipping the return type); a `;` means no body
        let mut b = pclose + 1;
        let mut body = None;
        while b < toks.len() {
            let x = &toks[b];
            if is_punct(x, ";") {
                break;
            }
            if x.kind == TokKind::Punct && (x.text == "(" || x.text == "[") {
                b = matches[b].map(|e| e + 1).unwrap_or(b + 1);
                continue;
            }
            if is_punct(x, "{") {
                if let Some(c) = matches[b] {
                    body = Some((b, c));
                }
                break;
            }
            b += 1;
        }
        if let Some(body) = body {
            fns.push((name, params, body));
        }
    }
    fns
}

/// Parameter names whose declared type mentions a share type.
fn param_taints(
    toks: &[Tok],
    matches: &[Option<usize>],
    pspan: (usize, usize),
) -> std::collections::BTreeSet<String> {
    let (a, b) = pspan;
    let mut names = std::collections::BTreeSet::new();
    // split on top-level commas
    let mut parts: Vec<Vec<usize>> = Vec::new();
    let mut cur: Vec<usize> = Vec::new();
    let mut k = a + 1;
    while k < b {
        let t = &toks[k];
        if t.kind == TokKind::Punct && (t.text == "(" || t.text == "[" || t.text == "{") {
            let end = matches[k].unwrap_or(k);
            for idx in k..=end.min(b.saturating_sub(1)) {
                cur.push(idx);
            }
            k = end + 1;
            continue;
        }
        if is_punct(t, "<") {
            let mut depth = 0i64;
            while k < b {
                if is_punct(&toks[k], "<") {
                    depth += 1;
                } else if is_punct(&toks[k], ">") {
                    depth -= 1;
                }
                cur.push(k);
                k += 1;
                if depth == 0 {
                    break;
                }
            }
            continue;
        }
        if is_punct(t, ",") {
            parts.push(std::mem::take(&mut cur));
            k += 1;
            continue;
        }
        cur.push(k);
        k += 1;
    }
    if !cur.is_empty() {
        parts.push(cur);
    }
    for p in parts {
        let Some(ci) = p.iter().position(|&i| is_punct(&toks[i], ":")) else {
            continue;
        };
        let name = p[..ci]
            .iter()
            .rev()
            .map(|&i| &toks[i])
            .find(|t| t.kind == TokKind::Ident && t.text != "mut" && t.text != "ref");
        let ty_has_share = p[ci..].iter().any(|&i| {
            toks[i].kind == TokKind::Ident && SHARE_TYPES.contains(&toks[i].text.as_str())
        });
        if let (Some(n), true) = (name, ty_has_share) {
            names.insert(n.text.clone());
        }
    }
    names
}

const ASSERT_MACROS: &[&str] =
    &["assert", "assert_eq", "assert_ne", "debug_assert", "debug_assert_eq", "debug_assert_ne"];

/// Flow-insensitive taint pass per function: share-typed params and results
/// of share-producing calls are tainted; `open`/`open_bits` sanitize; any
/// `if`/`while`/`match`/`assert!` condition or index expression over a
/// tainted local is a secret-dependent control/access pattern.
pub fn secret_independence(
    toks: &[Tok],
    matches: &[Option<usize>],
    tregions: &[(usize, usize)],
    out: &mut Vec<RawFinding>,
) {
    for (name, pspan, (bo, bc)) in find_fns(toks, matches) {
        if in_regions(bo, tregions) {
            continue;
        }
        let mut tainted = param_taints(toks, matches, pspan);
        let mut k = bo + 1;
        while k < bc {
            let t = &toks[k];
            if in_regions(k, tregions) {
                k += 1;
                continue;
            }
            if is_ident(t, "let") {
                k = secret_handle_let(toks, matches, k, bc, &mut tainted);
                continue;
            }
            if is_ident(t, "if") || is_ident(t, "while") {
                if toks.get(k + 1).is_some_and(|x| is_ident(x, "let")) {
                    k += 2;
                    continue;
                }
                let mut j = k + 1;
                let mut cond: Option<(usize, usize)> = None;
                while j < bc {
                    let x = &toks[j];
                    if x.kind == TokKind::Punct && (x.text == "(" || x.text == "[") {
                        j = matches[j].map(|e| e + 1).unwrap_or(j + 1);
                        continue;
                    }
                    if is_punct(x, "{") {
                        cond = Some((k + 1, j.saturating_sub(1)));
                        break;
                    }
                    j += 1;
                }
                if let Some((ca, cb)) = cond {
                    if let Some((line, id)) = tainted_use(toks, ca, cb, &tainted) {
                        out.push(RawFinding {
                            rule: Rule::Secret,
                            line,
                            msg: format!(
                                "`{}` condition depends on share-typed `{}` (fn {}); \
                                 open/reveal it first",
                                t.text, id, name
                            ),
                        });
                    }
                }
                k += 1;
                continue;
            }
            if is_ident(t, "match") {
                let mut j = k + 1;
                while j < bc {
                    let x = &toks[j];
                    if x.kind == TokKind::Punct && (x.text == "(" || x.text == "[") {
                        j = matches[j].map(|e| e + 1).unwrap_or(j + 1);
                        continue;
                    }
                    if is_punct(x, "{") {
                        break;
                    }
                    j += 1;
                }
                if let Some((line, id)) = tainted_use(toks, k + 1, j.saturating_sub(1), &tainted)
                {
                    out.push(RawFinding {
                        rule: Rule::Secret,
                        line,
                        msg: format!(
                            "`match` scrutinee depends on share-typed `{}` (fn {})",
                            id, name
                        ),
                    });
                }
                k += 1;
                continue;
            }
            if t.kind == TokKind::Ident
                && ASSERT_MACROS.contains(&t.text.as_str())
                && toks.get(k + 1).is_some_and(|x| is_punct(x, "!"))
                && toks.get(k + 2).is_some_and(|x| {
                    x.kind == TokKind::Punct && (x.text == "(" || x.text == "[")
                })
            {
                let g = k + 2;
                if let Some(end) = matches[g] {
                    if let Some((line, id)) =
                        tainted_use(toks, g + 1, end.saturating_sub(1), &tainted)
                    {
                        out.push(RawFinding {
                            rule: Rule::Secret,
                            line,
                            msg: format!(
                                "assertion depends on share-typed `{}` (fn {})",
                                id, name
                            ),
                        });
                    }
                    k = end + 1;
                    continue;
                }
            }
            if is_punct(t, "[") && k > bo + 1 && toks[k - 1].kind == TokKind::Ident {
                if let Some(end) = matches[k] {
                    if let Some((line, id)) =
                        tainted_use(toks, k + 1, end.saturating_sub(1), &tainted)
                    {
                        out.push(RawFinding {
                            rule: Rule::Secret,
                            line,
                            msg: format!(
                                "index depends on share-typed `{}` (fn {}) — a \
                                 secret-dependent access pattern",
                                id, name
                            ),
                        });
                    }
                }
            }
            k += 1;
        }
    }
}

/// One `let` statement: update the taint set, return the index after it.
fn secret_handle_let(
    toks: &[Tok],
    matches: &[Option<usize>],
    k: usize,
    bc: usize,
    tainted: &mut std::collections::BTreeSet<String>,
) -> usize {
    // pattern: everything up to a single `=` (not `==`) or `;`
    let mut j = k + 1;
    while j < bc {
        let x = &toks[j];
        if is_punct(x, "=") && !toks.get(j + 1).is_some_and(|n| is_punct(n, "=")) {
            break;
        }
        if is_punct(x, ";") {
            break;
        }
        j += 1;
    }
    if j >= bc || is_punct(&toks[j], ";") {
        return j + 1;
    }
    // binding idents: snake_case names outside type-annotation position
    let mut binds: Vec<String> = Vec::new();
    let mut in_ty = false;
    for x in &toks[k + 1..j] {
        if is_punct(x, ":") {
            in_ty = true;
        }
        if x.kind == TokKind::Punct && matches!(x.text.as_str(), "," | "(" | "{" | "|") {
            in_ty = false;
        }
        if x.kind == TokKind::Ident
            && !in_ty
            && x.text.chars().next().is_some_and(|c| c.is_lowercase())
            && !matches!(x.text.as_str(), "mut" | "ref" | "if" | "let")
        {
            binds.push(x.text.clone());
        }
    }
    // rhs: from after `=` to the `;` at delimiter depth 0
    let rhs_start = j + 1;
    let mut r = rhs_start;
    while r < bc {
        let x = &toks[r];
        if x.kind == TokKind::Punct && (x.text == "(" || x.text == "[" || x.text == "{") {
            r = matches[r].map(|e| e + 1).unwrap_or(r + 1);
            continue;
        }
        if is_punct(x, ";") {
            break;
        }
        r += 1;
    }
    let rhs_end = r.saturating_sub(1);
    let mut is_sanitized = false;
    let mut is_source = false;
    let mut uses_taint = false;
    let mut i = rhs_start;
    while i <= rhs_end && i < toks.len() {
        let x = &toks[i];
        if x.kind != TokKind::Ident {
            i += 1;
            continue;
        }
        let is_call = toks.get(i + 1).is_some_and(|n| is_punct(n, "("));
        if is_call && SANITIZERS.contains(&x.text.as_str()) {
            is_sanitized = true;
        } else if is_call && SHARE_SOURCES.contains(&x.text.as_str()) {
            is_source = true;
        } else if tainted.contains(&x.text) {
            let field_access = i > rhs_start && is_punct(&toks[i - 1], ".");
            if !field_access {
                let (public, _) = publicly_projected(toks, i, rhs_end);
                if !public {
                    uses_taint = true;
                }
            }
        }
        i += 1;
    }
    if is_sanitized {
        for b in &binds {
            tainted.remove(b);
        }
    } else if is_source || uses_taint {
        for b in binds {
            tainted.insert(b);
        }
    } else {
        for b in &binds {
            tainted.remove(b);
        }
    }
    r + 1
}

// ------------------------------------------------------------------ panic

const PANIC_MACROS: &[&str] = &["panic", "todo", "unimplemented", "unreachable"];

/// `unwrap()`/`expect()`/panicking macros in connection-path modules: a
/// malformed frame or poisoned lock must surface as a typed error, never
/// kill a reader/writer/shard thread.
pub fn panic_hygiene(toks: &[Tok], tregions: &[(usize, usize)], out: &mut Vec<RawFinding>) {
    for (k, t) in toks.iter().enumerate() {
        if t.kind != TokKind::Ident || in_regions(k, tregions) {
            continue;
        }
        if (t.text == "unwrap" || t.text == "expect")
            && k > 0
            && is_punct(&toks[k - 1], ".")
            && toks.get(k + 1).is_some_and(|x| is_punct(x, "("))
        {
            out.push(RawFinding {
                rule: Rule::Panic,
                line: t.line,
                msg: format!(
                    ".{}() in a connection-path module; surface a typed \
                     NetError/RejectCode instead",
                    t.text
                ),
            });
        } else if PANIC_MACROS.contains(&t.text.as_str())
            && toks.get(k + 1).is_some_and(|x| is_punct(x, "!"))
        {
            out.push(RawFinding {
                rule: Rule::Panic,
                line: t.line,
                msg: format!("{}! in a connection-path module", t.text),
            });
        }
    }
}

// ----------------------------------------------------------------- unsafe

/// `unsafe` anywhere outside the allow-listed SIMD kernel modules. The crate
/// sets `unsafe_code = "deny"` (Cargo.toml) and the two kernel files opt out
/// with a scoped `#![allow(unsafe_code)]`; this rule closes the loop by
/// making new opt-outs visible to the lint gate, not just to code review.
/// No test-region exemption: test code has no more business with `unsafe`
/// than production code does. (`unsafe_code` inside the allow attribute
/// lexes as a single distinct ident, so it does not fire.)
pub fn unsafe_confinement(toks: &[Tok], out: &mut Vec<RawFinding>) {
    for t in toks {
        if is_ident(t, "unsafe") {
            out.push(RawFinding {
                rule: Rule::Unsafe,
                line: t.line,
                msg: "`unsafe` outside the allow-listed SIMD kernel modules \
                      (he/simd.rs, ot/simd.rs); keep unsafe confined there"
                    .to_string(),
            });
        }
    }
}
