//! CLI: `cargo run -p mpc-lint [-- [--json <path>] [root]]`.
//!
//! Lints every `.rs` file under `root` (default `rust/src`, i.e. the main
//! crate when run from the workspace root), prints findings, and exits
//! non-zero if any unallowed finding remains — the CI gate.

use std::path::PathBuf;
use std::process::ExitCode;

fn main() -> ExitCode {
    let mut root = PathBuf::from("rust/src");
    let mut json_out: Option<PathBuf> = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_out = Some(PathBuf::from(p)),
                None => {
                    eprintln!("--json requires a path");
                    return ExitCode::from(2);
                }
            },
            "--help" | "-h" => {
                println!("usage: mpc-lint [--json <path>] [root]   (default root: rust/src)");
                return ExitCode::SUCCESS;
            }
            _ => root = PathBuf::from(a),
        }
    }
    if !root.is_dir() {
        eprintln!("mpc-lint: root {:?} is not a directory (run from the workspace root)", root);
        return ExitCode::from(2);
    }
    let findings = match mpc_lint::lint_tree(&root) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("mpc-lint: {}", e);
            return ExitCode::from(2);
        }
    };
    let mut sorted = findings;
    sorted.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    for f in &sorted {
        println!("{}", f.render());
    }
    let unallowed = sorted.iter().filter(|f| !f.allowed).count();
    let allowed = sorted.len() - unallowed;
    println!("mpc-lint: {} unallowed finding(s), {} allowed", unallowed, allowed);
    if let Some(p) = json_out {
        if let Err(e) = std::fs::write(&p, mpc_lint::report::to_json(&sorted)) {
            eprintln!("mpc-lint: writing {:?}: {}", p, e);
            return ExitCode::from(2);
        }
    }
    if unallowed > 0 {
        ExitCode::FAILURE
    } else {
        ExitCode::SUCCESS
    }
}
