// must-FIRE twice: a panicking macro and an unwrap on a decode path.
pub fn decode(b: &[u8]) -> u64 {
    if b.len() < 8 {
        panic!("short frame");
    }
    u64::from_le_bytes(b[..8].try_into().unwrap())
}
