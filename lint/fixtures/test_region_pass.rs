// must-PASS: tests may unwrap freely — `#[cfg(test)]` items are skipped.
pub fn shift(v: u64) -> u64 {
    v.rotate_left(1)
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwraps_freely() {
        let v: Option<u64> = Some(3);
        assert_eq!(v.unwrap(), 3);
    }
}
