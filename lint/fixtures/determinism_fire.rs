// must-FIRE: wall clock, ambient RNG, and hash-order iteration in a
// transcript-affecting module (linted as protocols/fixture.rs).
use std::collections::HashMap;
use std::time::Instant;

pub fn leaky(xs: &[u64]) -> u64 {
    let t0 = Instant::now();
    let mut m: HashMap<u64, u64> = HashMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
    }
    let r: u64 = rand::thread_rng().gen();
    t0.elapsed().as_nanos() as u64 ^ r ^ m.values().sum::<u64>()
}
