// must-FIRE: a hash container in the silent-OT extension path (linted as
// ot/silent.rs). Noisy-row bookkeeping iterated in hash order would make
// the correction stream — and with it the transcript digest — differ run
// to run, breaking spill/dealer bit-identity.
use std::collections::HashMap;

pub fn noisy_rows(idx: &[u32]) -> Vec<(u32, u64)> {
    let mut m: HashMap<u32, u64> = HashMap::new();
    for &i in idx {
        *m.entry(i / 256).or_insert(0) += 1;
    }
    m.into_iter().map(|(k, v)| (k, v)).collect()
}
