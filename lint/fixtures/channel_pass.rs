// must-PASS: send/recv sequences mirror position by position, and a
// symmetric exchange pairs with itself.
pub fn mirrored(ctx: &mut Ctx, xs: &[u64]) -> Vec<u64> {
    if ctx.is_p0() {
        ctx.ch.send_u64s(xs);
        ctx.ch.recv_u64s()
    } else {
        let got = ctx.ch.recv_u64s();
        ctx.ch.send_u64s(xs);
        got
    }
}

pub fn symmetric(ctx: &mut Ctx, xs: &[u64]) -> Vec<u64> {
    if ctx.is_p0() {
        ctx.ch.exchange_u64s(xs)
    } else {
        ctx.ch.exchange_u64s(xs)
    }
}
