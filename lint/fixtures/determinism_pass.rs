// must-PASS: ordered map, no clocks, no ambient RNG.
use std::collections::BTreeMap;

pub fn stable(xs: &[u64]) -> u64 {
    let mut m: BTreeMap<u64, u64> = BTreeMap::new();
    for &x in xs {
        *m.entry(x).or_insert(0) += 1;
    }
    m.values().sum()
}
