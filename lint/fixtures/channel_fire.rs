// must-FIRE: the P0 arm sends then receives, the P1 arm only receives —
// once frames coalesce this deadlocks (P1 waits on a send P0 never flushes).
pub fn unbalanced(ctx: &mut Ctx, xs: &[u64]) -> Vec<u64> {
    if ctx.is_p0() {
        ctx.ch.send_u64s(xs);
        ctx.ch.recv_u64s()
    } else {
        ctx.ch.recv_u64s()
    }
}
