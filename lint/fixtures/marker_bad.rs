// must-FIRE (rule `marker`): a suppression without a written reason.
pub fn f(v: Option<u64>) -> u64 {
    // mpc-lint: allow(panic)
    v.unwrap_or(0)
}
