// must-PASS when linted at the allow-listed rels (he/simd.rs, ot/simd.rs):
// a scoped opt-out plus an intrinsics-style unsafe kernel, the shape the
// real SIMD modules take. (`unsafe_code` in the attribute lexes as one
// ident distinct from `unsafe`, so it never fires anywhere.)
#![allow(unsafe_code)]

pub fn try_kernel(v: &mut [u64]) -> bool {
    unsafe { kernel(v) };
    true
}

unsafe fn kernel(v: &mut [u64]) {
    for x in v.iter_mut() {
        *x = x.wrapping_mul(3);
    }
}
