// must-FIRE twice: a branch condition and an index expression both depend
// on an unopened comparison share.
pub fn branch_on_share(e: &mut Mpc, x: &[Ring]) -> Vec<u64> {
    let m = e.cmp_gt_const(x, 7);
    if m[0] == 1 {
        return vec![];
    }
    let mut out = vec![0u64; 4];
    out[m[0] as usize] = 1;
    out
}
