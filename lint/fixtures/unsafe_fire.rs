// must-FIRE twice: an `unsafe` block and an `unsafe fn`, both outside the
// allow-listed SIMD kernel modules.
pub fn read_first(v: &[u64]) -> u64 {
    unsafe { *v.as_ptr() }
}

pub unsafe fn add_wild(p: *const u64, q: *const u64) -> u64 {
    (*p).wrapping_add(*q)
}
