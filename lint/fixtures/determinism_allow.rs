// must-PASS via marker: the finding fires but is suppressed with a reason.
pub fn stamp() -> std::time::Instant {
    // mpc-lint: allow(determinism) reason="telemetry only; never serialized"
    std::time::Instant::now()
}
