// must-PASS: shape projections of shares are public, and a value that
// flowed through `open` may drive control flow.
pub fn branch_on_opened(e: &mut Mpc, x: &[Ring]) -> Vec<u64> {
    let n = x.len();
    let m = e.cmp_gt_const(x, 7);
    assert_eq!(m.len(), n);
    let opened = e.open(&m);
    if opened[0] == 1 && n > 0 {
        return vec![0; n];
    }
    m
}
