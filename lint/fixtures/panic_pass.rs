// must-PASS: the same decode surfaces a typed error instead of panicking.
pub fn decode(b: &[u8]) -> Result<u64, NetError> {
    if b.len() < 8 {
        return Err(NetError::Frame(format!("short u64: {} bytes", b.len())));
    }
    let mut w = [0u8; 8];
    w.copy_from_slice(&b[..8]);
    Ok(u64::from_le_bytes(w))
}
