//! Quickstart: the prepare → session → infer lifecycle end-to-end.
//!
//! One model is ring-encoded once ([`PreparedModel::prepare`]), one two-party
//! session is started once ([`Session::start`] — HE keygen + base OTs on a
//! persistent P0/P1 thread pair), and then *several* private CipherPrune
//! requests run through it paying only the online protocol. The first
//! response is validated against (a) the Rust plaintext reference and (b) the
//! AOT XLA oracle artifact produced by `make artifacts` — all three layers
//! composing.
//!
//! PERF: the online phase runs the HE/OT hot loops on a per-party worker
//! pool sized from the host (override with `EngineConfig::threads(..)` or
//! `THREADS=1`); outputs and transcripts are identical at any setting — see
//! "Performance model" in the coordinator docs.
//!
//! TRANSPORT: set `TRANSPORT=tcp` (real loopback sockets), `sim`/`sim-wan`
//! (NetModel delay injection), or `mem` (default) to pick the channel
//! backend — logits, decisions, and wire digests are identical on all of
//! them; only wall time changes. For two separate OS processes, see the
//! `cipherprune party` subcommand.
//!
//!     cargo run --release --example quickstart
//!     TRANSPORT=tcp cargo run --release --example quickstart

use std::sync::Arc;

use cipherprune::coordinator::{EngineConfig, EngineKind, PreparedModel, Session};
use cipherprune::net::TransportSpec;
use cipherprune::nn::{forward_masked, ForwardOptions, ModelWeights, ThresholdSchedule, Workload};
use cipherprune::runtime::{artifact, TensorF32, XlaRuntime};
use cipherprune::util::bench::{fmt_bytes, fmt_duration};

fn main() {
    // 1. model + input — trained artifacts when present, salient init otherwise
    let weights = ModelWeights::load(&artifact("weights.bin")).unwrap_or_else(|_| {
        ModelWeights::salient(&cipherprune::nn::ModelConfig::tiny(), 42)
    });
    let cfg = weights.config.clone();
    let schedule = ThresholdSchedule::load(&artifact("thresholds.json"))
        .unwrap_or_else(|| ThresholdSchedule::default_for(cfg.n_layers))
        .fit_layers(cfg.n_layers);
    let sample = &Workload::qnli_like(&cfg, 16).batch(1, 3)[0];
    println!("model {} | {} tokens ({} real)", cfg.name, sample.ids.len(), sample.real_len);

    // 2. offline, once per model: ring-encode the weights
    let model = Arc::new(PreparedModel::prepare(Arc::new(weights)));

    // 3. offline, once per engine kind: start a reusable two-party session.
    //    Server P0 holds the prepared weights, client P1 holds the tokens;
    //    both parties run in-process over a byte-counted channel.
    let transport = std::env::var("TRANSPORT")
        .ok()
        .map(|name| TransportSpec::by_name(&name).expect("TRANSPORT=mem|tcp|sim|sim-wan"))
        .unwrap_or(TransportSpec::Mem);
    let ec = EngineConfig::new(EngineKind::CipherPrune)
        .he_n(4096)
        .schedule(schedule.clone())
        .transport(transport.clone());
    let mut session = Session::start(model, ec).expect("session start");
    println!(
        "session setup {} over {} ({} one-time traffic)",
        fmt_duration(session.setup_wall_s()),
        transport.label(),
        fmt_bytes(session.setup_stats().bytes as f64),
    );

    // 4. online: serve requests through the live session
    let private = session.infer(&sample.ids).expect("inference");
    println!(
        "\n[private]   logits {:?}  pred {}  ({}, {} traffic)",
        private.logits,
        private.predicted(),
        fmt_duration(private.wall_s),
        fmt_bytes(private.total_stats().bytes as f64),
    );
    for (i, s) in private.layer_stats.iter().enumerate() {
        println!("  layer {i}: {} → {} tokens ({} high-degree)", s.n_in, s.n_kept, s.n_high);
    }
    // further requests reuse the session — no keygen, no base OTs
    for (i, s) in Workload::qnli_like(&cfg, 16).batch(2, 9).iter().enumerate() {
        let r = session.infer(&s.ids).expect("inference");
        println!(
            "[request {}] pred {}  online {} ({} traffic)",
            i + 2,
            r.predicted(),
            fmt_duration(r.wall_s),
            fmt_bytes(r.total_stats().bytes as f64),
        );
    }

    // 5. plaintext reference (same pruning AND padding semantics, f64 —
    //    the masked oracle strips the pad run exactly like the session does)
    let reference = forward_masked(
        &session.model().weights,
        &sample.ids,
        &ForwardOptions::cipherprune(schedule, true),
    );
    println!("[reference] logits {:?}  pred {}", reference.logits, reference.predicted());
    let max_err = private
        .logits
        .iter()
        .zip(&reference.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("  max |Δ| vs reference: {max_err:.4} (fixed-point noise)");
    assert!(max_err < 0.3, "protocol must track the reference");

    // 6. XLA oracle (Layer 1+2 lowered to HLO, executed via PJRT)
    let hlo = artifact("model.hlo.txt");
    if !hlo.exists() {
        println!("[xla oracle] skipped — run `make artifacts`");
    } else {
        match XlaRuntime::cpu() {
            Ok(mut rt) => {
                let meta = std::fs::read_to_string(artifact("meta.json")).unwrap();
                let meta = cipherprune::util::json::Json::parse(&meta).unwrap();
                let seq = meta.get("seq_len").and_then(|v| v.as_usize()).unwrap_or(16);
                let n = seq.min(sample.ids.len());
                let mut onehot = vec![0f32; seq * cfg.vocab];
                for (i, &id) in sample.ids.iter().take(n).enumerate() {
                    onehot[i * cfg.vocab + id] = 1.0;
                }
                let out = rt
                    .run_f32(&hlo, &[TensorF32::new(onehot, vec![seq as i64, cfg.vocab as i64])])
                    .expect("oracle");
                println!("[xla oracle] logits {:?} (unpruned polynomial forward)", out[0].data);
            }
            Err(e) => println!("[xla oracle] skipped — {e:#}"),
        }
    }
    println!("\nquickstart OK");
}
