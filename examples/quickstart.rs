//! Quickstart: one private CipherPrune inference end-to-end, validated
//! against (a) the Rust plaintext reference and (b) the AOT XLA oracle
//! artifact produced by `make artifacts` — all three layers composing.
//!
//!     cargo run --release --example quickstart

use cipherprune::coordinator::{run_inference, EngineConfig, EngineKind};
use cipherprune::nn::{forward, ForwardOptions, ModelWeights, ThresholdSchedule, Workload};
use cipherprune::runtime::{artifact, TensorF32, XlaRuntime};
use cipherprune::util::bench::{fmt_bytes, fmt_duration};

fn main() {
    // 1. model + input — trained artifacts when present, salient init otherwise
    let weights = ModelWeights::load(&artifact("weights.bin")).unwrap_or_else(|_| {
        ModelWeights::salient(&cipherprune::nn::ModelConfig::tiny(), 42)
    });
    let cfg = weights.config.clone();
    let schedule = ThresholdSchedule::load(&artifact("thresholds.json"))
        .unwrap_or_else(|| ThresholdSchedule::default_for(cfg.n_layers))
        .fit_layers(cfg.n_layers);
    let sample = &Workload::qnli_like(&cfg, 16).batch(1, 3)[0];
    println!("model {} | {} tokens ({} real)", cfg.name, sample.ids.len(), sample.real_len);

    // 2. private inference: server P0 holds weights, client P1 holds tokens;
    //    both parties run in-process over a byte-counted channel.
    let mut ec = EngineConfig::new(EngineKind::CipherPrune, cfg.n_layers);
    ec.he_n = 4096;
    ec.schedule = schedule.clone();
    let private = run_inference(&ec, &weights, &sample.ids);
    println!(
        "\n[private]   logits {:?}  pred {}  ({}, {} traffic)",
        private.logits,
        private.predicted(),
        fmt_duration(private.wall_s),
        fmt_bytes(private.total_stats().bytes as f64),
    );
    for (i, s) in private.layer_stats.iter().enumerate() {
        println!("  layer {i}: {} → {} tokens ({} high-degree)", s.n_in, s.n_kept, s.n_high);
    }

    // 3. plaintext reference (same pruning semantics, f64)
    let reference = forward(&weights, &sample.ids, &ForwardOptions::cipherprune(schedule, true));
    println!("[reference] logits {:?}  pred {}", reference.logits, reference.predicted());
    let max_err = private
        .logits
        .iter()
        .zip(&reference.logits)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("  max |Δ| vs reference: {max_err:.4} (fixed-point noise)");
    assert!(max_err < 0.3, "protocol must track the reference");

    // 4. XLA oracle (Layer 1+2 lowered to HLO, executed via PJRT)
    let hlo = artifact("model.hlo.txt");
    if hlo.exists() {
        let meta = std::fs::read_to_string(artifact("meta.json")).unwrap();
        let meta = cipherprune::util::json::Json::parse(&meta).unwrap();
        let seq = meta.get("seq_len").and_then(|v| v.as_usize()).unwrap_or(16);
        let n = seq.min(sample.ids.len());
        let mut onehot = vec![0f32; seq * cfg.vocab];
        for (i, &id) in sample.ids.iter().take(n).enumerate() {
            onehot[i * cfg.vocab + id] = 1.0;
        }
        let mut rt = XlaRuntime::cpu().expect("PJRT");
        let out = rt
            .run_f32(&hlo, &[TensorF32::new(onehot, vec![seq as i64, cfg.vocab as i64])])
            .expect("oracle");
        println!("[xla oracle] logits {:?} (unpruned polynomial forward)", out[0].data);
    } else {
        println!("[xla oracle] skipped — run `make artifacts`");
    }
    println!("\nquickstart OK");
}
