//! Scalability demo (mini Fig. 9): runtime and traffic of BOLT w/o W.E.,
//! BOLT, and CipherPrune as the input length grows. The quadratic SoftMax
//! cost dominates the unpruned engines; CipherPrune's progressive pruning
//! flattens the curve.
//!
//! Each engine kind runs through ONE reusable [`Session`] for the whole
//! sweep — the model is encoded once and keys/base OTs are set up once per
//! engine, so the measured per-point cost is the online protocol only (the
//! quantity the paper's figure compares).
//!
//! PERF: each session's hot loops run on a host-sized worker pool (pin with
//! `THREADS=n`); the sweep's wall times scale with cores while traffic stays
//! byte-identical. `cargo run --release --bin bench_e2e` records the
//! single-thread vs host-pool speedup.
//!
//!     cargo run --release --example scalability
//!     SCALE_SEQS="16,32,64" cargo run --release --example scalability

use std::sync::Arc;

use cipherprune::coordinator::{EngineConfig, EngineKind, PreparedModel, Session};
use cipherprune::net::NetModel;
use cipherprune::nn::{ModelConfig, ModelWeights, Workload};
use cipherprune::util::bench::{fmt_bytes, fmt_duration, Table};

fn main() {
    let seqs: Vec<usize> = std::env::var("SCALE_SEQS")
        .unwrap_or_else(|_| "8,16,32".to_string())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let cfg = ModelConfig::tiny();
    let weights = ModelWeights::salient(&cfg, 42);
    // offline: encode once, one session per compared engine
    let model = Arc::new(PreparedModel::prepare(Arc::new(weights)));
    let engines = [EngineKind::BoltNoWe, EngineKind::Bolt, EngineKind::CipherPrune];
    let mut sessions: Vec<Session> = engines
        .iter()
        .map(|&kind| {
            // distinct seed per kind: independent sessions must not share
            // dealer/OT randomness streams
            let ec = EngineConfig::new(kind).he_n(2048).seed(0xC1F4E9 ^ kind.ordinal());
            Session::start(model.clone(), ec).expect("session start")
        })
        .collect();

    let mut table = Table::new(
        "online runtime vs input length (tiny model, LAN-modeled)",
        &["tokens", "engine", "compute", "traffic", "LAN total", "kept@last"],
    );
    for &seq in &seqs {
        let sample = &Workload::qnli_like(&cfg, seq).batch(1, 5)[0];
        for session in sessions.iter_mut() {
            let r = session.infer(&sample.ids).expect("inference");
            let t = r.total_stats();
            table.row(vec![
                seq.to_string(),
                session.kind().name().to_string(),
                fmt_duration(r.wall_s),
                fmt_bytes(t.bytes as f64),
                fmt_duration(r.wall_s + NetModel::LAN.time(&t)),
                r.layer_stats.last().map(|s| s.n_kept).unwrap_or(0).to_string(),
            ]);
        }
    }
    table.print();
    println!("\nCipherPrune's curve flattens as pruning removes quadratic SoftMax work.");
}
