//! Scalability demo (mini Fig. 9): runtime and traffic of BOLT w/o W.E.,
//! BOLT, and CipherPrune as the input length grows. The quadratic SoftMax
//! cost dominates the unpruned engines; CipherPrune's progressive pruning
//! flattens the curve.
//!
//!     cargo run --release --example scalability
//!     SCALE_SEQS="16,32,64" cargo run --release --example scalability

use cipherprune::coordinator::{run_inference, EngineConfig, EngineKind};
use cipherprune::net::NetModel;
use cipherprune::nn::{ModelConfig, ModelWeights, Workload};
use cipherprune::util::bench::{fmt_bytes, fmt_duration, Table};

fn main() {
    let seqs: Vec<usize> = std::env::var("SCALE_SEQS")
        .unwrap_or_else(|_| "8,16,32".to_string())
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let cfg = ModelConfig::tiny();
    let weights = ModelWeights::salient(&cfg, 42);

    let engines = [EngineKind::BoltNoWe, EngineKind::Bolt, EngineKind::CipherPrune];
    let mut table = Table::new(
        "runtime vs input length (tiny model, LAN-modeled)",
        &["tokens", "engine", "compute", "traffic", "LAN total", "kept@last"],
    );
    for &seq in &seqs {
        let sample = &Workload::qnli_like(&cfg, seq).batch(1, 5)[0];
        for kind in engines {
            let mut ec = EngineConfig::new(kind, cfg.n_layers);
            ec.he_n = 2048;
            let r = run_inference(&ec, &weights, &sample.ids);
            let t = r.total_stats();
            table.row(vec![
                seq.to_string(),
                kind.name().to_string(),
                fmt_duration(r.wall_s),
                fmt_bytes(t.bytes as f64),
                fmt_duration(r.wall_s + NetModel::LAN.time(&t)),
                r.layer_stats.last().map(|s| s.n_kept).unwrap_or(0).to_string(),
            ]);
        }
    }
    table.print();
    println!("\nCipherPrune's curve flattens as pruning removes quadratic SoftMax work.");
}
