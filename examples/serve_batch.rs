//! End-to-end serving driver (the repo's headline validation workload):
//! a router with a length-bucketed dynamic batcher serves a mixed stream of
//! private-inference requests against trained-or-salient weights, reporting
//! per-request latency, throughput, accuracy vs ground truth, and the
//! per-engine metrics registry.
//!
//! The router follows the prepare → session → infer lifecycle: it ring-encodes
//! the model exactly once at construction ([`PreparedModel`]) and keeps a
//! per-engine-kind cache of live two-party [`Session`]s, so every request
//! after the first pays only the online protocol — no weight encoding, no
//! HE keygen, no base OTs. The metrics report's `offline:` line shows how
//! much setup was amortized.
//!
//! Batches FUSE: a released bucket of same-kind requests runs as one
//! block-masked pipeline pass (one weight-ciphertext pass for the whole
//! batch), so the report's `runs=` counts batches while `requests=` counts
//! members and `amortized=` shows the per-request share. Buckets are a
//! scheduling notion only — padding is stripped at the session boundary
//! (lengths are public), so results are bucket-independent.
//!
//! PERF: each live session runs two party threads whose hot loops use a
//! worker pool (`RouterConfig::threads`). The default divides the host
//! across the worker budget (`host / (2 × workers)`, min 1) so concurrent
//! session slots don't oversubscribe each other; pin it to override.
//!
//!     cargo run --release --example serve_batch            # quick
//!     SERVE_REQS=16 SERVE_SEQ=32 cargo run --release --example serve_batch

use std::sync::Arc;

use cipherprune::coordinator::{
    BatchPolicy, EngineKind, InferenceRequest, Router, RouterConfig,
};
use cipherprune::net::TransportSpec;
use cipherprune::nn::{ModelWeights, ThresholdSchedule, Workload};
use cipherprune::runtime::artifact;
use cipherprune::util::bench::fmt_duration;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn main() {
    let n_req = env_usize("SERVE_REQS", 8);
    let seq = env_usize("SERVE_SEQ", 16);
    let weights = Arc::new(ModelWeights::load(&artifact("weights.bin")).unwrap_or_else(
        |_| ModelWeights::salient(&cipherprune::nn::ModelConfig::tiny(), 42),
    ));
    let cfg = weights.config.clone();
    let schedule = ThresholdSchedule::load(&artifact("thresholds.json"))
        .unwrap_or_else(|| ThresholdSchedule::default_for(cfg.n_layers))
        .fit_layers(cfg.n_layers);

    let mut router = Router::new(
        weights,
        RouterConfig {
            policy: BatchPolicy {
                max_batch: 4,
                linger: std::time::Duration::from_millis(10),
                min_bucket: 8,
                max_tokens: cfg.max_seq,
            },
            workers: 4,
            he_n: 4096,
            schedule: Some(schedule),
            threads: None,
            transport: TransportSpec::Mem,
        },
    );

    // mixed stream: short and long requests, two engines
    let wl_short = Workload::qnli_like(&cfg, seq);
    let wl_long = Workload::qnli_like(&cfg, (seq * 2).min(cfg.max_seq));
    let mut reqs = Vec::new();
    let mut truth = Vec::new();
    for (i, s) in wl_short.batch(n_req / 2, 21).into_iter().enumerate() {
        truth.push(s.label);
        reqs.push(InferenceRequest { id: i as u64, ids: s.ids, engine: EngineKind::CipherPrune });
    }
    for (j, s) in wl_long.batch(n_req - n_req / 2, 22).into_iter().enumerate() {
        truth.push(s.label);
        reqs.push(InferenceRequest {
            id: (n_req / 2 + j) as u64,
            ids: s.ids,
            engine: if j % 2 == 0 { EngineKind::CipherPrune } else { EngineKind::Bolt },
        });
    }

    println!("serving {n_req} mixed-length private requests…");
    let t0 = std::time::Instant::now();
    let resp = router.process(reqs);
    let wall = t0.elapsed().as_secs_f64();

    let mut correct = 0usize;
    for r in &resp {
        let res = r.result.as_ref().expect("healthy in-process serving");
        let ok = res.predicted() == truth[r.id as usize];
        correct += ok as usize;
        println!(
            "  req {:>2}  bucket {:>3}  latency {:>9}  pred {} {}",
            r.id,
            r.bucket,
            fmt_duration(r.latency_s),
            res.predicted(),
            if ok { "✓" } else { "✗" }
        );
    }
    println!(
        "\nthroughput {:.2} req/s | accuracy {}/{} | wall {}",
        resp.len() as f64 / wall,
        correct,
        resp.len(),
        fmt_duration(wall)
    );
    println!("\n{}", router.metrics.report());
    assert_eq!(resp.len(), n_req);
}
